//! `ShardedStore<R>` — the generic persistent-store core (ISSUE 4
//! tentpole). `CacheStore` (oracle results) and `ModelStore` (fitted
//! surrogates) used to mirror the same shard/lock/flush protocol line
//! for line; every drift between the two copies was a correctness
//! hazard. This module owns the protocol once, and both stores are now
//! thin typed wrappers:
//!
//! - **Content-hash shard routing**: u64 keys (splitmix-finalized
//!   hashes) route to one of N shard files by their top byte.
//! - **Schema-tagged envelopes, pluggable codecs** (ISSUE 7): the store
//!   owns the envelope (`v`, `kind`, `key`, `used`); a [`Record`]
//!   implementation encodes and decodes the payload fields; a
//!   [`Codec`] (`v1` JSONL / `v2` binary, see `store::codec`) owns the
//!   frame bytes. Reads auto-detect the codec per shard file by
//!   extension, so mixed-version dirs just work; writes use the
//!   configured codec and a flush collapses a shard to it. Unknown
//!   schema versions and corrupt frames are skipped on load — a torn
//!   or foreign record is never served.
//! - **Streaming lazy loads** (ISSUE 7): a shard file is scanned the
//!   first time a key routed to it is requested — but the scan only
//!   tokenizes the envelope fields and records each body as an
//!   undecoded frame span ([`SlotState::Lazy`]). The full payload
//!   decode is deferred until a record is actually materialized by a
//!   matching `get` (or a rewrite), so warm runs that touch a fraction
//!   of a shard never tree-parse the rest (`lazy_skips` counts them).
//! - **Index sidecars** (ISSUE 7): each flushed shard gets a
//!   `<shard>.idx` bloom + key→offset sidecar (see `store::sidecar`).
//!   A point lookup on an unloaded shard consults it first: a bloom or
//!   table miss answers "miss" with no file scan at all, a hit fetches
//!   exactly one frame (`sidecar_hits`). Sidecars are disposable —
//!   missing/torn/stale ones fall back to the streaming scan and are
//!   rebuilt best-effort (`sidecar_rebuilds`).
//! - **Atomic flush**: dirty shards rewrite via temp + rename (same
//!   directory, so the rename is atomic) in sorted `(kind, key)` order
//!   — shard files are byte-deterministic for a given entry set and
//!   codec.
//! - **`.store.lock` ordering + merge-on-flush**: flushes serialize
//!   through a directory lock (stolen after a staleness window, so a
//!   crashed holder never wedges the store), and each dirty shard is
//!   re-scanned from disk right before its rewrite so records another
//!   process flushed since our last read are folded in, never dropped.
//!
//! On top of the shared protocol sit the first **lifecycle policies**
//! ([`StorePolicy`]):
//!
//! - **Eviction** — LRU by last-used stamp under a byte / record /
//!   age budget. Stamps are *logical epochs* (the store's open
//!   counter, persisted in `meta.json`), not wall-clock times: two runs
//!   replaying the same operation sequence assign identical stamps, so
//!   eviction decisions — and therefore shard bytes — stay
//!   deterministic. Evicting a key plants a **tombstone** record, so
//!   merge-on-flush in a concurrent process cannot resurrect the
//!   evicted entry from its own stale shard read — for as long as the
//!   tombstone is on disk. Compaction reclaims tombstones, which
//!   narrows that guarantee: a concurrent writer that loaded the key
//!   before the eviction and flushes after the compact can write the
//!   record back. That is deliberate and safe for a cache — by the
//!   determinism contract the resurrected value is identical, so the
//!   cost is bytes, not correctness, and any active budget simply
//!   re-evicts it at its next flush or compact. Budgets apply to
//!   live-record bytes; they are enforced on every flush that has work
//!   to do, and on every compaction.
//! - **Compaction** — [`ShardedStore::compact`] (CLI: `fso store
//!   compact`) loads and merges every shard, applies the eviction
//!   policy, then rewrites shards dropping tombstones, superseded /
//!   unparseable frames, and orphaned temp files — and, since the
//!   rewrite always uses the configured codec, compaction *transcodes*
//!   shards written under the other codec (`transcoded_records`). A
//!   shard whose bytes would not change is left untouched, so
//!   compaction is idempotent and never perturbs a warm start: reads
//!   before and after compact are identical. Flush auto-compacts when
//!   the dead-frame ratio on disk (tombstones + garbage + shadowed
//!   frames over total frames) crosses `auto_compact_ratio`.
//!
//! Pending-count contract (ISSUE 4 satellite): `StoreStats::pending`
//! counts exactly the records that are not yet durable — per-slot
//! dirty flags, not "everything in a dirty shard" — so a
//! merge-on-flush that folds disk records into memory can no longer
//! drift the count.

use std::borrow::Cow;
use std::collections::hash_map::Entry;
use std::collections::{HashMap, HashSet};
use std::fs;
use std::io::{Read as IoRead, Seek, SeekFrom};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::rng::hash_bytes;

use super::codec::{Codec, EncodeError, Frame};
use super::fault::{self, FlushFault};
use super::lock::{tmp_path, write_atomic, DirLock};
use super::sidecar::{idx_path, SidecarIndex};

pub use super::codec::{hex_key, parse_hex_key};

/// Reserved record kind for eviction tombstones (never a payload kind).
pub const TOMB_KIND: &str = "tomb";

/// A record family a `ShardedStore` can persist. The store owns the
/// envelope fields (`v`, `kind`, `key`, `used`); implementations own
/// only the payload.
pub trait Record: Clone + PartialEq + Send {
    /// Envelope kind tag — also the deterministic sort class within a
    /// shard file. Must never be [`TOMB_KIND`]. Borrowing from `self`
    /// is encouraged (`Cow::Borrowed`): the tag is compared on every
    /// `get` hit, so an owned allocation per call is pure overhead.
    fn kind(&self) -> Cow<'_, str>;
    /// Append the payload fields to the record object.
    fn encode(&self, out: &mut Vec<(&'static str, Json)>);
    /// Decode a payload from the full record object; `None` reads as a
    /// corrupt frame (skipped on load, dropped at compaction).
    fn decode(kind: &str, rec: &Json) -> Option<Self>
    where
        Self: Sized;
}

/// Static knobs a typed wrapper fixes once for its record family.
#[derive(Debug, Clone)]
pub struct StoreConfig {
    /// Record schema version; bump on any layout change. Loaders skip
    /// records whose tag does not match.
    pub schema_version: u64,
    /// Shard-file count for fresh directories (existing directories
    /// keep the count recorded in `meta.json`).
    pub default_shards: usize,
    /// Shard file prefix (`shard` -> `shard-003.fsb`).
    pub file_prefix: &'static str,
    /// Noun used in error messages ("cache dir", "model store").
    pub label: &'static str,
    /// Lifecycle policy (eviction budgets + auto-compaction).
    pub policy: StorePolicy,
    /// Frame codec new shard files are written with. Reads always
    /// auto-detect per file, so this only steers writes.
    pub codec: Codec,
}

/// Eviction / compaction policy. `Default` is unbounded with no
/// auto-compaction; [`StorePolicy::default_auto`] is what the wrappers
/// ship — unbounded, but auto-compacting once half the disk frames are
/// dead.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StorePolicy {
    /// Evict LRU records until live-record bytes fit this budget.
    /// (Shard files may transiently exceed it by tombstone overhead
    /// until the next compaction.)
    pub max_bytes: Option<u64>,
    /// Evict LRU records until at most this many live records remain.
    pub max_records: Option<usize>,
    /// Evict records whose last *persisted* use is more than this many
    /// epochs old (an epoch is one open of the store directory; 0 =
    /// only the current epoch survives). Caveat: runs with no budget
    /// configured never rewrite shards for reads, so a fully-warm
    /// unbounded run does not advance stamps on disk — pair `max_age`
    /// with budget-carrying runs (or use the byte/record budgets,
    /// whose *relative* LRU order is unaffected), and expect
    /// write-age semantics otherwise.
    pub max_age_epochs: Option<u64>,
    /// Auto-compact after a flush when dead disk frames (tombstones +
    /// garbage + shadowed) exceed this fraction of all frames.
    pub auto_compact_ratio: Option<f64>,
}

impl StorePolicy {
    /// The wrappers' default: unbounded, auto-compacting at 50% dead.
    pub fn default_auto() -> StorePolicy {
        StorePolicy { auto_compact_ratio: Some(0.5), ..StorePolicy::default() }
    }

    /// Whether any eviction budget is set (budget enforcement loads
    /// every shard at flush, so it only runs when asked for).
    pub fn is_bounded(&self) -> bool {
        self.max_bytes.is_some() || self.max_records.is_some() || self.max_age_epochs.is_some()
    }
}

/// Counter snapshot (wrappers re-surface these through their own
/// stats structs).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StoreStats {
    /// Lookups answered with a live record of the requested kind.
    pub hits: usize,
    /// Lookups that found nothing (or a kind mismatch / tombstone).
    pub misses: usize,
    /// Shard files scanned so far (lazy loading).
    pub shard_loads: usize,
    /// `flush` calls that wrote at least one shard.
    pub flushes: usize,
    /// Live records currently held in memory (decoded or lazy).
    pub entries: usize,
    /// Records (live or tombstone) not yet durable on disk — exactly
    /// the per-slot dirty flags, never "everything in a dirty shard".
    pub pending: usize,
    /// Tombstones currently held (reclaimed at compaction).
    pub tombstones: usize,
    /// Serialized bytes of the live records (the eviction byte budget
    /// is judged against this). Exact whenever `max_bytes` is set;
    /// without a byte budget, records put since the last flush count
    /// as 0 until a flush or load renders them.
    pub live_bytes: u64,
    /// Records evicted by policy or `evict` since open.
    pub evictions: usize,
    /// Compaction passes since open (explicit + automatic).
    pub compactions: usize,
    /// This instance's logical epoch (open counter of the directory).
    pub epoch: u64,
    /// Frames loaded as undecoded spans whose body was never
    /// tree-parsed (the streaming-scan win).
    pub lazy_skips: usize,
    /// Lazy frames actually decoded into records (materialized by a
    /// matching `get` or a shard rewrite).
    pub full_decodes: usize,
    /// Point lookups answered by a sidecar index — a definitive miss
    /// or a single-frame fetch, either way with no shard scan.
    pub sidecar_hits: usize,
    /// Sidecars rebuilt after being found missing, torn, or stale.
    pub sidecar_rebuilds: usize,
    /// Records rewritten from the other codec's frame format during a
    /// flush or compaction of a mixed-codec directory.
    pub transcoded_records: usize,
}

/// What one compaction pass did.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CompactReport {
    /// Shard files rewritten or removed (unchanged shards are skipped).
    pub shards_rewritten: usize,
    /// Live records in the compacted store.
    pub live_records: usize,
    /// Tombstones dropped from memory + disk.
    pub tombstones_dropped: usize,
    /// Dead disk frames reclaimed (tombstones, unparseable garbage,
    /// superseded-schema records, shadowed duplicates).
    pub dead_lines_dropped: usize,
    /// Records evicted by the policy during this pass.
    pub evicted: usize,
    /// Total shard-file bytes before / after.
    pub bytes_before: u64,
    pub bytes_after: u64,
}

impl std::fmt::Display for CompactReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} live records | dropped {} tombstones / {} dead lines | evicted {} | {} -> {} bytes | {} shards rewritten",
            self.live_records,
            self.tombstones_dropped,
            self.dead_lines_dropped,
            self.evicted,
            self.bytes_before,
            self.bytes_after,
            self.shards_rewritten
        )
    }
}

#[derive(Clone)]
enum SlotState<R> {
    Live(R),
    /// Scanned envelope with the body still encoded: the frame decodes
    /// only when a matching `get` or a shard rewrite materializes it.
    Lazy { kind: String, frame: Box<[u8]>, codec: Codec },
    /// Evicted: reads miss; persisted as a tombstone record so a
    /// concurrent process's merge-on-flush cannot resurrect the key.
    Tomb,
}

#[derive(Clone)]
struct Slot<R> {
    state: SlotState<R>,
    /// Logical last-used stamp (the store epoch that last touched it).
    used: u64,
    /// Serialized frame length in bytes (incl. the v1 newline) — the
    /// unit the byte budget is accounted in.
    bytes: usize,
    /// Not yet durable on disk.
    dirty: bool,
}

#[derive(Clone, Copy)]
struct ShardMeta {
    loaded: bool,
    /// Needs a rewrite at the next flush (dirty slots, stamp bumps
    /// under an active policy, or evictions).
    dirty: bool,
    /// Frame stats from the most recent scan / rewrite of the disk
    /// file (drives the auto-compaction ratio).
    disk_lines: usize,
    disk_dead: usize,
}

/// Per-shard sidecar cache: probed lazily on the first point lookup
/// into an unloaded shard.
#[derive(Clone)]
enum SideState {
    Unprobed,
    /// No usable index (missing/torn/stale sidecar, mixed-codec shard,
    /// or no shard file at all): lookups fall back to the scan.
    Unusable,
    Ready { codec: Codec, idx: SidecarIndex },
}

/// How a sidecar answered one point lookup.
enum SideLookup {
    /// Definitively absent — no scan, no fetch.
    Miss,
    /// One frame fetched and parked as a lazy slot.
    Frame,
    /// No usable sidecar: caller must scan the shard.
    Fallback,
}

struct Inner<R> {
    slots: HashMap<u64, Slot<R>>,
    shards: Vec<ShardMeta>,
    sides: Vec<SideState>,
}

/// Disk-backed, sharded, read-through/write-behind store. Thread-safe;
/// share one instance across services via `Arc`.
pub struct ShardedStore<R: Record> {
    dir: PathBuf,
    cfg: StoreConfig,
    n_shards: usize,
    /// Logical clock: how many times this directory has been opened
    /// (persisted in `meta.json`). All accesses in one instance stamp
    /// with this epoch, so stamps are independent of thread schedule —
    /// and shard bytes stay deterministic under parallel access.
    epoch: u64,
    inner: Mutex<Inner<R>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    shard_loads: AtomicUsize,
    flushes: AtomicUsize,
    evictions: AtomicUsize,
    compactions: AtomicUsize,
    lazy_skips: AtomicUsize,
    full_decodes: AtomicUsize,
    sidecar_hits: AtomicUsize,
    sidecar_rebuilds: AtomicUsize,
    transcoded_records: AtomicUsize,
}

impl<R: Record> ShardedStore<R> {
    /// Open (creating if needed) a store directory with the config's
    /// default shard count. An existing directory keeps the shard
    /// count it was created with (recorded in `meta.json`), so
    /// reopening with a different default never mis-routes keys. Every
    /// open bumps the directory's logical epoch.
    pub fn open(dir: impl Into<PathBuf>, cfg: StoreConfig) -> Result<ShardedStore<R>> {
        let n = cfg.default_shards;
        ShardedStore::open_sharded(dir, cfg, n)
    }

    /// Open with an explicit shard count (ignored when the directory
    /// already records one).
    pub fn open_sharded(
        dir: impl Into<PathBuf>,
        cfg: StoreConfig,
        n_shards: usize,
    ) -> Result<ShardedStore<R>> {
        let dir = dir.into();
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating {} {}", cfg.label, dir.display()))?;
        let meta_path = dir.join("meta.json");
        let (n_shards, epoch, fresh) = match fs::read_to_string(&meta_path) {
            Ok(text) => {
                let meta = Json::parse(&text)
                    .with_context(|| format!("parsing {}", meta_path.display()))?;
                let v = meta.get("v").as_usize().unwrap_or(0) as u64;
                anyhow::ensure!(
                    v == cfg.schema_version,
                    "{} {} has schema v{v}, this binary expects v{}",
                    cfg.label,
                    dir.display(),
                    cfg.schema_version
                );
                let shards = meta
                    .get("shards")
                    .as_usize()
                    .filter(|&s| s > 0)
                    .with_context(|| format!("{}: bad shard count", meta_path.display()))?;
                // epoch was introduced with the store core; a pre-core
                // meta.json (no field) reads as epoch 0
                let epoch = meta.get("epoch").as_usize().unwrap_or(0) as u64;
                (shards, epoch.saturating_add(1), false)
            }
            // only a genuinely absent meta.json means "fresh directory";
            // any other read error (permissions, transient IO) must not
            // silently re-shard an existing store under a new layout
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => (n_shards.max(1), 1, true),
            Err(e) => {
                return Err(e).with_context(|| format!("reading {}", meta_path.display()))
            }
        };
        // persist the bumped epoch (concurrent opens race benignly:
        // the rename is atomic and the epoch only steers LRU policy)
        let meta = Json::obj(vec![
            ("v", Json::from(cfg.schema_version as usize)),
            ("shards", Json::from(n_shards)),
            ("epoch", Json::from(epoch as usize)),
        ]);
        let wrote = write_atomic(&meta_path, format!("{meta}\n").as_bytes());
        if fresh {
            // a store we cannot create is an error...
            wrote?;
        } else {
            // ...but an existing store on a read-only mount must stay
            // readable: the epoch bump is best-effort (LRU stamps just
            // stop advancing; pure readers never flush anyway)
            let _ = wrote;
        }
        Ok(ShardedStore {
            dir,
            cfg,
            n_shards,
            epoch,
            inner: Mutex::new(Inner {
                slots: HashMap::new(),
                shards: vec![
                    ShardMeta { loaded: false, dirty: false, disk_lines: 0, disk_dead: 0 };
                    n_shards
                ],
                sides: vec![SideState::Unprobed; n_shards],
            }),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            shard_loads: AtomicUsize::new(0),
            flushes: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
            compactions: AtomicUsize::new(0),
            lazy_skips: AtomicUsize::new(0),
            full_decodes: AtomicUsize::new(0),
            sidecar_hits: AtomicUsize::new(0),
            sidecar_rebuilds: AtomicUsize::new(0),
            transcoded_records: AtomicUsize::new(0),
        })
    }

    /// Replace the lifecycle policy (builder-style, before sharing).
    pub fn with_policy(mut self, policy: StorePolicy) -> ShardedStore<R> {
        self.cfg.policy = policy;
        self
    }

    /// Replace the write codec (builder-style, before sharing). Reads
    /// auto-detect regardless.
    pub fn with_codec(mut self, codec: Codec) -> ShardedStore<R> {
        self.cfg.codec = codec;
        self
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn shard_count(&self) -> usize {
        self.n_shards
    }

    pub fn policy(&self) -> &StorePolicy {
        &self.cfg.policy
    }

    pub fn codec(&self) -> Codec {
        self.cfg.codec
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    fn shard_of(&self, key: u64) -> usize {
        // content-hash prefix routing: the top byte spreads uniformly
        // because keys come out of splitmix-finalized hashes
        ((key >> 56) as usize) % self.n_shards
    }

    fn shard_path_for(&self, shard: usize, codec: Codec) -> PathBuf {
        self.dir
            .join(format!("{}-{shard:03}.{}", self.cfg.file_prefix, codec.file_ext()))
    }

    /// The active-codec path — where writes go.
    fn shard_path(&self, shard: usize) -> PathBuf {
        self.shard_path_for(shard, self.cfg.codec)
    }

    // ---- frame (de)serialization -----------------------------------
    //
    // The codec owns the bytes; the store hands it the envelope fields
    // plus the record payload. Both codecs render deterministically
    // (sorted object keys), so a rendered frame is a pure function of
    // its fields.

    fn append_live(
        &self,
        out: &mut Vec<u8>,
        key: u64,
        rec: &R,
        used: u64,
    ) -> Result<usize, EncodeError> {
        let mut payload: Vec<(&'static str, Json)> = Vec::new();
        rec.encode(&mut payload);
        let kind = rec.kind();
        self.cfg.codec.imp().append_frame(
            out,
            self.cfg.schema_version,
            key,
            used,
            kind.as_ref(),
            payload,
        )
    }

    fn append_tomb(&self, out: &mut Vec<u8>, key: u64, used: u64) -> Result<usize, EncodeError> {
        self.cfg.codec.imp().append_frame(
            out,
            self.cfg.schema_version,
            key,
            used,
            TOMB_KIND,
            Vec::new(),
        )
    }

    /// Scan a shard file into the slots the first time a key routed
    /// to it is requested.
    fn load_shard(&self, inner: &mut Inner<R>, shard: usize) {
        if inner.shards[shard].loaded {
            return;
        }
        inner.shards[shard].loaded = true;
        self.shard_loads.fetch_add(1, Ordering::Relaxed);
        self.scan_shard(inner, shard);
    }

    /// The raw disk-to-memory merge under `load_shard`, the flush-time
    /// re-read, and the compact-time sweep — streaming: the codec scan
    /// surfaces envelopes and raw frame spans, and bodies park as
    /// [`SlotState::Lazy`] without a tree parse. Both codec files are
    /// scanned (active first), so mixed-codec dirs auto-detect; within
    /// and across files the first frame per key wins. Unknown schema
    /// versions and corrupt frames are skipped (a half-written or
    /// foreign record must never sink a run). Merge rule: in-memory
    /// entries win unless the disk stamp is strictly newer *and* ours
    /// is clean — a fresher use or eviction by a concurrent process
    /// replaces a clean slot; our own unflushed data is never clobbered.
    /// Also refreshes the shard's dead-frame stats (tombstones +
    /// garbage + shadowed duplicates) for auto-compaction.
    fn scan_shard(&self, inner: &mut Inner<R>, shard: usize) {
        let mut total = 0usize;
        let mut dead = 0usize;
        let mut lazy = 0usize;
        let mut seen: HashSet<u64> = HashSet::new();
        let schema = self.cfg.schema_version;
        for codec in [self.cfg.codec, self.cfg.codec.other()] {
            let Ok(bytes) = fs::read(self.shard_path_for(shard, codec)) else {
                continue;
            };
            let slots = &mut inner.slots;
            let st = codec.imp().scan(&bytes, schema, &mut |f: Frame<'_>| {
                if !seen.insert(f.key) {
                    // duplicate: first frame wins, later copies are
                    // shadowed (and reclaimable)
                    dead += 1;
                    return;
                }
                let state = if f.kind.as_ref() == TOMB_KIND {
                    dead += 1; // tombstones are reclaimable at compaction
                    SlotState::Tomb
                } else {
                    lazy += 1;
                    SlotState::Lazy {
                        kind: f.kind.into_owned(),
                        frame: Box::from(f.bytes),
                        codec,
                    }
                };
                let bytes_len = f.bytes.len() + codec.frame_overhead();
                match slots.entry(f.key) {
                    Entry::Vacant(v) => {
                        v.insert(Slot { state, used: f.used, bytes: bytes_len, dirty: false });
                    }
                    Entry::Occupied(mut o) => {
                        let cur = o.get();
                        if !cur.dirty && f.used > cur.used {
                            o.insert(Slot {
                                state,
                                used: f.used,
                                bytes: bytes_len,
                                dirty: false,
                            });
                        }
                    }
                }
            });
            total += st.frames;
            dead += st.dead;
        }
        if lazy > 0 {
            self.lazy_skips.fetch_add(lazy, Ordering::Relaxed);
        }
        inner.shards[shard].disk_lines = total;
        inner.shards[shard].disk_dead = dead;
    }

    /// Decode a lazy slot in place. A frame whose payload fails to
    /// decode is dead: the slot is dropped (reads miss) and the next
    /// rewrite reclaims it.
    fn materialize(&self, inner: &mut Inner<R>, shard: usize, key: u64) {
        let decoded = match inner.slots.get(&key) {
            Some(Slot { state: SlotState::Lazy { kind, frame, codec }, .. }) => {
                self.full_decodes.fetch_add(1, Ordering::Relaxed);
                Some(
                    codec
                        .imp()
                        .decode_payload(frame, self.cfg.schema_version)
                        .and_then(|obj| R::decode(kind, &obj)),
                )
            }
            _ => None,
        };
        match decoded {
            Some(Some(r)) => {
                if let Some(slot) = inner.slots.get_mut(&key) {
                    slot.state = SlotState::Live(r);
                }
            }
            Some(None) => {
                inner.slots.remove(&key);
                inner.shards[shard].disk_dead += 1;
            }
            None => {}
        }
    }

    /// Probe the sidecar situation for a shard: which codec file
    /// exists, and whether its `.idx` is present, parseable, and
    /// matches the file length. Returns the state plus a codec to
    /// rebuild for when the shard file is fine but the sidecar is not.
    fn probe_sidecar(&self, shard: usize) -> (SideState, Option<Codec>) {
        let mut found: Option<(Codec, u64)> = None;
        for codec in [self.cfg.codec, self.cfg.codec.other()] {
            if let Ok(m) = fs::metadata(self.shard_path_for(shard, codec)) {
                if found.is_some() {
                    // both codec files present: only a scan can merge
                    // them (first-frame-wins across files)
                    return (SideState::Unusable, None);
                }
                found = Some((codec, m.len()));
            }
        }
        let Some((codec, len)) = found else {
            return (SideState::Unusable, None); // no shard file at all
        };
        let path = self.shard_path_for(shard, codec);
        let idx = fs::read_to_string(idx_path(&path))
            .ok()
            .and_then(|t| SidecarIndex::parse(&t))
            .filter(|i| i.codec == codec && i.len == len);
        match idx {
            Some(idx) => (SideState::Ready { codec, idx }, None),
            None => (SideState::Unusable, Some(codec)),
        }
    }

    /// Re-derive a shard's sidecar from its body (the authoritative
    /// bytes) and write it atomically, best-effort.
    fn rebuild_sidecar(&self, shard: usize, codec: Codec) {
        let path = self.shard_path_for(shard, codec);
        let Ok(body) = fs::read(&path) else {
            return;
        };
        let mut entries: Vec<(u64, u64, u64)> = Vec::new();
        let mut seen: HashSet<u64> = HashSet::new();
        codec.imp().scan(&body, self.cfg.schema_version, &mut |f: Frame<'_>| {
            // the seen-set must gate *before* the tombstone test: a
            // tomb frame shadowing a later live duplicate means the
            // key is dead, and indexing the shadowed copy would serve
            // a record the scan path correctly misses
            if seen.insert(f.key) && f.kind.as_ref() != TOMB_KIND {
                entries.push((f.key, f.offset as u64, f.bytes.len() as u64));
            }
        });
        let idx = SidecarIndex::build(codec, &body, &entries);
        let _ = write_atomic(&idx_path(&path), idx.render().as_bytes());
        self.sidecar_rebuilds.fetch_add(1, Ordering::Relaxed);
    }

    /// Read exactly one frame span out of a shard file and verify it:
    /// the re-scan of the fetched bytes must yield a single live frame
    /// for the expected key, or the sidecar that pointed here is stale.
    fn fetch_frame(
        &self,
        shard: usize,
        codec: Codec,
        off: u64,
        len: u64,
        key: u64,
    ) -> Option<(u64, String, Box<[u8]>)> {
        let path = self.shard_path_for(shard, codec);
        let mut file = fs::File::open(&path).ok()?;
        file.seek(SeekFrom::Start(off)).ok()?;
        let mut buf = vec![0u8; len as usize];
        file.read_exact(&mut buf).ok()?;
        let mut hit: Option<(u64, String, Box<[u8]>)> = None;
        let st = codec.imp().scan(&buf, self.cfg.schema_version, &mut |f: Frame<'_>| {
            if hit.is_none()
                && f.offset == 0
                && f.bytes.len() == buf.len()
                && f.key == key
                && f.kind.as_ref() != TOMB_KIND
            {
                hit = Some((f.used, f.kind.into_owned(), Box::from(f.bytes)));
            }
        });
        if st.frames != 1 || st.dead != 0 {
            return None;
        }
        hit
    }

    /// Answer a point lookup on an *unloaded* shard from its sidecar,
    /// if one is usable. A fetched frame parks as a clean lazy slot;
    /// any defect flips the shard to scan-fallback and rebuilds the
    /// sidecar from the shard body.
    fn sidecar_get(&self, inner: &mut Inner<R>, shard: usize, key: u64) -> SideLookup {
        if matches!(inner.sides[shard], SideState::Unprobed) {
            let (state, rebuild) = self.probe_sidecar(shard);
            inner.sides[shard] = state;
            if let Some(codec) = rebuild {
                // shard file is fine, sidecar is missing/torn/stale:
                // this lookup falls back to the scan, the next open
                // finds a fresh index
                self.rebuild_sidecar(shard, codec);
            }
        }
        let (codec, off, len) = match &inner.sides[shard] {
            SideState::Ready { codec, idx } => {
                if !idx.may_contain(key) {
                    return SideLookup::Miss;
                }
                match idx.lookup(key) {
                    Some((off, len)) => (*codec, off, len),
                    None => return SideLookup::Miss,
                }
            }
            _ => return SideLookup::Fallback,
        };
        match self.fetch_frame(shard, codec, off, len, key) {
            Some((used, kind, frame)) => {
                let bytes = frame.len() + codec.frame_overhead();
                inner.slots.insert(
                    key,
                    Slot { state: SlotState::Lazy { kind, frame, codec }, used, bytes, dirty: false },
                );
                SideLookup::Frame
            }
            None => {
                // the index pointed at garbage: it is stale relative to
                // the shard body — discard it and re-derive
                inner.sides[shard] = SideState::Unusable;
                self.rebuild_sidecar(shard, codec);
                SideLookup::Fallback
            }
        }
    }

    /// Force every shard into memory (CLI stats and union assertions;
    /// normal traffic should rely on lazy loading).
    pub fn load_all(&self) {
        let mut inner = self.inner.lock().unwrap();
        for s in 0..self.n_shards {
            self.load_shard(&mut inner, s);
        }
    }

    /// Merge every shard from disk, one scan per shard: a first touch
    /// goes through the lazy-load path; an already-loaded shard
    /// re-scans to fold in records concurrent processes flushed since
    /// we read it. Call with the `DirLock` held — then the disk state
    /// cannot move underneath, and the merged view stays current for
    /// the rest of the locked section.
    fn merge_all(&self, inner: &mut Inner<R>) {
        for s in 0..self.n_shards {
            if inner.shards[s].loaded {
                self.scan_shard(inner, s);
            } else {
                self.load_shard(inner, s);
            }
        }
    }

    /// Live record of `kind` for `key`, if known. A key held under a
    /// different kind — or a tombstone — reads as a miss. On an
    /// unloaded shard the sidecar answers first: a definitive index
    /// miss never touches the shard file, an index hit fetches one
    /// frame, and only a fallback scans the shard. A hit bumps the LRU
    /// stamp to the current epoch (marking the shard for rewrite only
    /// when an eviction budget is active, so unbounded warm runs stay
    /// read-only on disk).
    pub fn get(&self, kind: &str, key: u64) -> Option<R> {
        let mut inner = self.inner.lock().unwrap();
        let shard = self.shard_of(key);
        if !inner.shards[shard].loaded && !inner.slots.contains_key(&key) {
            match self.sidecar_get(&mut inner, shard, key) {
                SideLookup::Miss => {
                    self.sidecar_hits.fetch_add(1, Ordering::Relaxed);
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    return None;
                }
                SideLookup::Frame => {
                    self.sidecar_hits.fetch_add(1, Ordering::Relaxed);
                }
                SideLookup::Fallback => self.load_shard(&mut inner, shard),
            }
        }
        // decode a lazy slot only when the kind matches: a mismatch is
        // a miss and must not pay (or count) a full-tree parse
        let lazy_match = matches!(
            inner.slots.get(&key),
            Some(Slot { state: SlotState::Lazy { kind: k, .. }, .. }) if k.as_str() == kind
        );
        if lazy_match {
            self.materialize(&mut inner, shard, key);
        }
        let epoch = self.epoch;
        let mut bumped = false;
        let hit = match inner.slots.get_mut(&key) {
            Some(slot) => match &slot.state {
                SlotState::Live(r) if r.kind() == kind => {
                    if slot.used < epoch {
                        slot.used = epoch;
                        bumped = true;
                    }
                    Some(r.clone())
                }
                _ => None,
            },
            None => None,
        };
        if bumped && self.cfg.policy.is_bounded() {
            inner.shards[shard].dirty = true;
        }
        match hit {
            Some(r) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(r)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Record a value (write-behind: durable at the next flush). An
    /// identical live value only refreshes the LRU stamp; a changed
    /// value, a resurrection over a tombstone, or a fresh key dirties
    /// the slot — that is how a corrupt artifact gets repaired after
    /// its fallback recompute.
    pub fn put(&self, key: u64, rec: R) {
        let mut inner = self.inner.lock().unwrap();
        let shard = self.shard_of(key);
        let epoch = self.epoch;
        // a lazy slot of the same kind must decode before the
        // same-value check can compare records
        let lazy_same_kind = matches!(
            inner.slots.get(&key),
            Some(Slot { state: SlotState::Lazy { kind, .. }, .. })
                if kind.as_str() == rec.kind().as_ref()
        );
        if lazy_same_kind {
            self.materialize(&mut inner, shard, key);
        }
        let same = matches!(
            inner.slots.get(&key),
            Some(Slot { state: SlotState::Live(cur), .. }) if *cur == rec
        );
        if same {
            let mut bumped = false;
            if let Some(slot) = inner.slots.get_mut(&key) {
                if slot.used < epoch {
                    slot.used = epoch;
                    bumped = true;
                }
            }
            if bumped && self.cfg.policy.is_bounded() {
                inner.shards[shard].dirty = true;
            }
        } else {
            // measure the serialized size only when a byte budget needs
            // it — rendering on every put would double serialization
            // work for the common unbounded store (flush's render pass
            // refreshes `bytes` to the exact length either way)
            let bytes = if self.cfg.policy.max_bytes.is_some() {
                // an unencodable record sizes as 0 here; the flush
                // render pass surfaces the EncodeError to the caller
                let mut scratch = Vec::new();
                self.append_live(&mut scratch, key, &rec, epoch)
                    .map_or(0, |n| n + self.cfg.codec.frame_overhead())
            } else {
                0
            };
            inner
                .slots
                .insert(key, Slot { state: SlotState::Live(rec), used: epoch, bytes, dirty: true });
            inner.shards[shard].dirty = true;
        }
    }

    /// Explicitly evict a key: it reads as a miss from now on, and a
    /// tombstone persists the eviction so a concurrent writer's merge
    /// cannot resurrect a *staler* copy of the record. Advisory, not
    /// absolute: a concurrent process that used the key at a strictly
    /// newer epoch keeps it live through its own merge (and compaction
    /// reclaims tombstones — see the module docs); for a deterministic
    /// cache that only ever costs bytes, and budgets re-evict. Returns
    /// whether a live record was evicted.
    pub fn evict(&self, key: u64) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let shard = self.shard_of(key);
        self.load_shard(&mut inner, shard);
        let live = matches!(
            inner.slots.get(&key),
            Some(Slot { state: SlotState::Live(_) | SlotState::Lazy { .. }, .. })
        );
        if live {
            self.tombstone(&mut inner, key);
        }
        live
    }

    fn tombstone(&self, inner: &mut Inner<R>, key: u64) {
        let epoch = self.epoch;
        let bytes = {
            // tombstones carry no payload, so this cannot overflow a
            // length prefix in practice; size as 0 if it somehow does
            let mut scratch = Vec::new();
            self.append_tomb(&mut scratch, key, epoch)
                .map_or(0, |n| n + self.cfg.codec.frame_overhead())
        };
        inner
            .slots
            .insert(key, Slot { state: SlotState::Tomb, used: epoch, bytes, dirty: true });
        let shard = self.shard_of(key);
        inner.shards[shard].dirty = true;
        self.evictions.fetch_add(1, Ordering::Relaxed);
    }

    /// Enforce the eviction policy over the (fully loaded) slot map:
    /// age bound first, then LRU down to the byte / record budgets.
    /// Deterministic: candidates order by (stamp, key). Lazy slots are
    /// live records for policy purposes — their stamps and frame sizes
    /// are exact without a decode.
    fn apply_policy(&self, inner: &mut Inner<R>) {
        let pol = self.cfg.policy.clone();
        let epoch = self.epoch;
        if let Some(max_age) = pol.max_age_epochs {
            let mut expired: Vec<u64> = inner
                .slots
                .iter()
                .filter_map(|(&k, s)| {
                    let live = matches!(s.state, SlotState::Live(_) | SlotState::Lazy { .. });
                    (live && epoch.saturating_sub(s.used) > max_age).then_some(k)
                })
                .collect();
            expired.sort_unstable();
            for key in expired {
                self.tombstone(inner, key);
            }
        }
        let mut live: Vec<(u64, u64, usize)> = inner
            .slots
            .iter()
            .filter_map(|(&k, s)| match s.state {
                SlotState::Live(_) | SlotState::Lazy { .. } => Some((s.used, k, s.bytes)),
                SlotState::Tomb => None,
            })
            .collect();
        let mut bytes: u64 = live.iter().map(|&(_, _, b)| b as u64).sum();
        let mut count = live.len();
        let over = |bytes: u64, count: usize| {
            pol.max_bytes.is_some_and(|m| bytes > m)
                || pol.max_records.is_some_and(|m| count > m)
        };
        if !over(bytes, count) {
            return;
        }
        live.sort_unstable(); // (used, key, bytes): oldest stamp first
        let mut i = 0;
        while i < live.len() && over(bytes, count) {
            let (_, key, b) = live[i];
            self.tombstone(inner, key);
            bytes -= b as u64;
            count -= 1;
            i += 1;
        }
    }

    /// Serialize one shard's slots in sorted (kind, key) order under
    /// the active codec, materializing (and thereby transcoding) any
    /// lazy frames first. Refreshes each written slot's byte size to
    /// the exact rendered length and returns the live-frame table the
    /// sidecar is built from.
    fn render_shard(
        &self,
        inner: &mut Inner<R>,
        shard: usize,
    ) -> Result<RenderedShard, EncodeError> {
        // a rewrite re-encodes every record: lazy frames decode here,
        // and frames written under the other codec count as transcoded
        let lazy: Vec<(u64, bool)> = inner
            .slots
            .iter()
            .filter_map(|(&k, s)| match &s.state {
                SlotState::Lazy { codec, .. } if self.shard_of(k) == shard => {
                    Some((k, *codec != self.cfg.codec))
                }
                _ => None,
            })
            .collect();
        for &(k, transcode) in &lazy {
            self.materialize(inner, shard, k);
            if transcode && inner.slots.contains_key(&k) {
                self.transcoded_records.fetch_add(1, Ordering::Relaxed);
            }
        }
        let mut order: Vec<(String, u64)> = Vec::new();
        let mut tombs = 0usize;
        for (&key, slot) in &inner.slots {
            if self.shard_of(key) != shard {
                continue;
            }
            match &slot.state {
                SlotState::Live(r) => order.push((r.kind().into_owned(), key)),
                SlotState::Tomb => {
                    tombs += 1;
                    order.push((TOMB_KIND.to_string(), key));
                }
                SlotState::Lazy { .. } => unreachable!("lazy slots materialized above"),
            }
        }
        // sorted (kind, key) order: shard bytes are deterministic
        order.sort_by(|a, b| (a.0.as_str(), a.1).cmp(&(b.0.as_str(), b.1)));
        let mut body: Vec<u8> = Vec::new();
        let mut entries: Vec<(u64, u64, u64)> = Vec::new();
        let frames = order.len();
        for (kind, key) in &order {
            let off = body.len() as u64;
            let flen = {
                let slot = &inner.slots[key];
                match &slot.state {
                    SlotState::Live(r) => self.append_live(&mut body, *key, r, slot.used)?,
                    SlotState::Tomb => self.append_tomb(&mut body, *key, slot.used)?,
                    SlotState::Lazy { .. } => unreachable!("lazy slots materialized above"),
                }
            };
            if let Some(slot) = inner.slots.get_mut(key) {
                slot.bytes = flen + self.cfg.codec.frame_overhead();
            }
            if kind != TOMB_KIND {
                entries.push((*key, off, flen as u64));
            }
        }
        Ok(RenderedShard { body, entries, frames, tombs })
    }

    fn clear_slot_dirty(&self, inner: &mut Inner<R>, shard: usize) {
        for (&key, slot) in inner.slots.iter_mut() {
            if self.shard_of(key) == shard {
                slot.dirty = false;
            }
        }
    }

    fn auto_compact_due(&self, inner: &Inner<R>) -> bool {
        let Some(ratio) = self.cfg.policy.auto_compact_ratio else {
            return false;
        };
        let (lines, dead) = inner
            .shards
            .iter()
            .fold((0usize, 0usize), |a, s| (a.0 + s.disk_lines, a.1 + s.disk_dead));
        lines > 0 && (dead as f64) / (lines as f64) > ratio
    }

    /// Write every dirty shard atomically (temp + rename), serialized
    /// across processes by the directory lock and merged with the disk
    /// state first — a flush never drops entries: neither on-disk
    /// records this run did not happen to read, nor records a
    /// concurrent process flushed since. Each written shard also gets
    /// a fresh `.idx` sidecar (after the shard rename, so a crash
    /// between the two leaves data durable and the sidecar merely
    /// stale), and the other codec's file for that shard is removed —
    /// a flush collapses a mixed-codec shard to the active codec. When
    /// an eviction budget is active the policy is enforced first
    /// (which loads every shard). Returns the number of shard files
    /// written; may trigger an auto-compaction afterwards (see
    /// `StorePolicy`).
    pub fn flush(&self) -> Result<usize> {
        // cheap dirtiness pre-check, then take the cross-process lock
        // *without* holding the in-process Mutex: a contended DirLock
        // wait (up to the staleness window) must not stall every
        // worker thread doing get/put on the shared store
        {
            let inner = self.inner.lock().unwrap();
            if !inner.shards.iter().any(|s| s.dirty) {
                return Ok(0);
            }
        }
        let lock = DirLock::acquire(&self.dir)?;
        let mut inner = self.inner.lock().unwrap();
        let premerged = self.cfg.policy.is_bounded();
        if premerged {
            // merge every shard from disk *before* deciding evictions:
            // shards loaded long ago may hold stale LRU stamps, and
            // evicting on a stale view could tombstone a key a
            // concurrent process used (and stamped fresher) since —
            // its dirty tombstone would then survive the merge and
            // clobber the most-recently-used record instead of the
            // least.
            self.merge_all(&mut inner);
            self.apply_policy(&mut inner);
        }
        // recompute under the lock: another thread may have flushed
        let dirty: Vec<usize> =
            (0..self.n_shards).filter(|&s| inner.shards[s].dirty).collect();
        if dirty.is_empty() {
            return Ok(0);
        }
        for &shard in &dirty {
            lock.refresh();
            if !premerged {
                // merge-on-flush; redundant when merge_all already ran
                // under this same lock (the disk cannot have moved)
                self.scan_shard(&mut inner, shard);
                inner.shards[shard].loaded = true;
            }
            let r = self.render_shard(&mut inner, shard)?;
            let path = self.shard_path(shard);
            if fault::trip(FlushFault::BeforeRename) {
                // emulate a kill after the temp write, before the
                // rename: the temp file exists, the shard file is
                // untouched, and the directory lock stays behind (the
                // "process" died holding it)
                let _ = fs::write(tmp_path(&path), &r.body);
                std::mem::forget(lock);
                anyhow::bail!("injected crash before rename (store::fault)");
            }
            write_atomic(&path, &r.body)?;
            // the shard is now wholly under the active codec: drop the
            // other codec's file (its frames were merged above) and its
            // now-dangling sidecar
            let other = self.shard_path_for(shard, self.cfg.codec.other());
            let _ = fs::remove_file(idx_path(&other));
            let _ = fs::remove_file(&other);
            let idx = SidecarIndex::build(self.cfg.codec, &r.body, &r.entries);
            let ip = idx_path(&path);
            if fault::trip(FlushFault::IdxBeforeRename) {
                // emulate a kill after the shard rename with the
                // sidecar still staged: records are durable, the old
                // sidecar (if any) is stale against the new body, and
                // the lock is left behind
                let _ = fs::write(tmp_path(&ip), idx.render().as_bytes());
                std::mem::forget(lock);
                anyhow::bail!("injected crash before sidecar rename (store::fault)");
            }
            // sidecar writes are best-effort: the store must work
            // (scan-fallback) on a read-only or full disk
            let _ = write_atomic(&ip, idx.render().as_bytes());
            inner.sides[shard] = SideState::Unprobed;
            inner.shards[shard].dirty = false;
            inner.shards[shard].disk_lines = r.frames;
            inner.shards[shard].disk_dead = r.tombs;
            self.clear_slot_dirty(&mut inner, shard);
        }
        self.flushes.fetch_add(1, Ordering::Relaxed);
        if fault::trip(FlushFault::BeforeLockRelease) {
            // data is durable; the lock is abandoned as a crash would
            std::mem::forget(lock);
            anyhow::bail!("injected crash before lock release (store::fault)");
        }
        let auto = self.auto_compact_due(&inner);
        drop(inner);
        drop(lock);
        if auto {
            self.compact()?;
        }
        Ok(dirty.len())
    }

    /// Compaction pass: load + merge every shard, enforce the eviction
    /// policy, drop tombstones and dead frames, and rewrite only the
    /// shards whose bytes change (so a second compact is a no-op and a
    /// warm start straddling a compact replays identical reads). The
    /// rewrite uses the active codec, so compaction transcodes shards
    /// written under the other one. Also sweeps orphaned temp files
    /// left by killed writers and refreshes any sidecar that no longer
    /// matches its shard body. Serialized by the directory lock; also
    /// persists any pending writes.
    pub fn compact(&self) -> Result<CompactReport> {
        let lock = DirLock::acquire(&self.dir)?;
        let mut inner = self.inner.lock().unwrap();
        // merge-on-compact: fold in records concurrent processes
        // flushed since our lazy loads (one scan per shard)
        self.merge_all(&mut inner);
        let ev0 = self.evictions.load(Ordering::Relaxed);
        if self.cfg.policy.is_bounded() {
            self.apply_policy(&mut inner);
        }
        let mut rep = CompactReport {
            evicted: self.evictions.load(Ordering::Relaxed) - ev0,
            dead_lines_dropped: inner.shards.iter().map(|s| s.disk_dead).sum(),
            ..CompactReport::default()
        };
        let tomb_keys: Vec<u64> = inner
            .slots
            .iter()
            .filter_map(|(&k, s)| matches!(s.state, SlotState::Tomb).then_some(k))
            .collect();
        rep.tombstones_dropped = tomb_keys.len();
        for k in &tomb_keys {
            inner.slots.remove(k);
        }
        for shard in 0..self.n_shards {
            lock.refresh();
            let path = self.shard_path(shard);
            let other = self.shard_path_for(shard, self.cfg.codec.other());
            let active_before = fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
            let other_before = fs::metadata(&other).map(|m| m.len()).unwrap_or(0);
            rep.bytes_before += active_before + other_before;
            let r = self.render_shard(&mut inner, shard)?;
            if r.body.is_empty() {
                if active_before > 0 || other_before > 0 {
                    let _ = fs::remove_file(&path);
                    let _ = fs::remove_file(&other);
                    rep.shards_rewritten += 1;
                }
                let _ = fs::remove_file(idx_path(&path));
                let _ = fs::remove_file(idx_path(&other));
            } else {
                let unchanged = other_before == 0
                    && active_before == r.body.len() as u64
                    && fs::read(&path).map(|b| b == r.body).unwrap_or(false);
                if !unchanged {
                    write_atomic(&path, &r.body)?;
                    let _ = fs::remove_file(idx_path(&other));
                    let _ = fs::remove_file(&other);
                    rep.shards_rewritten += 1;
                }
                rep.bytes_after += r.body.len() as u64;
                // refresh the sidecar only when it is not already an
                // exact match for the body — the hash check keeps a
                // second compact byte-level idempotent (and quietly
                // heals sidecars torn by a crashed writer)
                let ip = idx_path(&path);
                let fresh = fs::read_to_string(&ip)
                    .ok()
                    .and_then(|t| SidecarIndex::parse(&t))
                    .is_some_and(|i| {
                        i.codec == self.cfg.codec
                            && i.len == r.body.len() as u64
                            && i.hash == hash_bytes(&r.body)
                    });
                if !fresh {
                    let idx = SidecarIndex::build(self.cfg.codec, &r.body, &r.entries);
                    let _ = write_atomic(&ip, idx.render().as_bytes());
                }
            }
            inner.sides[shard] = SideState::Unprobed;
            inner.shards[shard].dirty = false;
            inner.shards[shard].disk_lines = r.frames;
            inner.shards[shard].disk_dead = 0;
            self.clear_slot_dirty(&mut inner, shard);
            rep.live_records += r.frames;
        }
        // sweep crash leftovers: orphaned *shard* temp files from
        // killed writers (shard bodies and `.idx` sidecars both stage
        // as `.{prefix}-...tmp-...`). Meta temps are deliberately
        // spared — another process may be mid-open (the meta epoch
        // bump takes no DirLock), and deleting its staged temp would
        // fail that open.
        let tmp_prefix = format!(".{}-", self.cfg.file_prefix);
        if let Ok(rd) = fs::read_dir(&self.dir) {
            for e in rd.flatten() {
                let name = e.file_name();
                let name = name.to_string_lossy();
                if name.starts_with(tmp_prefix.as_str()) && name.contains(".tmp-") {
                    let _ = fs::remove_file(e.path());
                }
            }
        }
        self.compactions.fetch_add(1, Ordering::Relaxed);
        Ok(rep)
    }

    /// Snapshot the store counters. `pending` counts exactly the
    /// not-yet-durable slots (the ISSUE 4 drift fix). Lazy slots count
    /// as live entries — they serve reads, just without a decode yet.
    pub fn stats(&self) -> StoreStats {
        let inner = self.inner.lock().unwrap();
        let mut entries = 0usize;
        let mut tombstones = 0usize;
        let mut pending = 0usize;
        let mut live_bytes = 0u64;
        for slot in inner.slots.values() {
            match slot.state {
                SlotState::Live(_) | SlotState::Lazy { .. } => {
                    entries += 1;
                    live_bytes += slot.bytes as u64;
                }
                SlotState::Tomb => tombstones += 1,
            }
            if slot.dirty {
                pending += 1;
            }
        }
        StoreStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            shard_loads: self.shard_loads.load(Ordering::Relaxed),
            flushes: self.flushes.load(Ordering::Relaxed),
            entries,
            pending,
            tombstones,
            live_bytes,
            evictions: self.evictions.load(Ordering::Relaxed),
            compactions: self.compactions.load(Ordering::Relaxed),
            epoch: self.epoch,
            lazy_skips: self.lazy_skips.load(Ordering::Relaxed),
            full_decodes: self.full_decodes.load(Ordering::Relaxed),
            sidecar_hits: self.sidecar_hits.load(Ordering::Relaxed),
            sidecar_rebuilds: self.sidecar_rebuilds.load(Ordering::Relaxed),
            transcoded_records: self.transcoded_records.load(Ordering::Relaxed),
        }
    }

    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn shard_loads(&self) -> usize {
        self.shard_loads.load(Ordering::Relaxed)
    }

    pub fn flush_count(&self) -> usize {
        self.flushes.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> usize {
        self.evictions.load(Ordering::Relaxed)
    }

    pub fn compactions(&self) -> usize {
        self.compactions.load(Ordering::Relaxed)
    }

    pub fn lazy_skips(&self) -> usize {
        self.lazy_skips.load(Ordering::Relaxed)
    }

    pub fn full_decodes(&self) -> usize {
        self.full_decodes.load(Ordering::Relaxed)
    }

    pub fn sidecar_hits(&self) -> usize {
        self.sidecar_hits.load(Ordering::Relaxed)
    }

    pub fn sidecar_rebuilds(&self) -> usize {
        self.sidecar_rebuilds.load(Ordering::Relaxed)
    }

    pub fn transcoded_records(&self) -> usize {
        self.transcoded_records.load(Ordering::Relaxed)
    }
}

/// One shard serialized under the active codec, plus the live-frame
/// table its sidecar indexes.
struct RenderedShard {
    body: Vec<u8>,
    /// `(key, offset, frame_len)` for every live (non-tomb) frame.
    entries: Vec<(u64, u64, u64)>,
    frames: usize,
    tombs: usize,
}

impl<R: Record> Drop for ShardedStore<R> {
    /// Best-effort durability for callers that forget an explicit
    /// flush; errors are swallowed (Drop cannot fail).
    fn drop(&mut self) {
        let _ = self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, Clone, Copy, PartialEq)]
    struct TestRec {
        tag: &'static str,
        val: f64,
    }

    impl Record for TestRec {
        fn kind(&self) -> Cow<'_, str> {
            Cow::Borrowed(self.tag)
        }
        fn encode(&self, out: &mut Vec<(&'static str, Json)>) {
            out.push(("val", Json::from(self.val)));
        }
        fn decode(kind: &str, rec: &Json) -> Option<TestRec> {
            let tag = match kind {
                "a" => "a",
                "b" => "b",
                _ => return None,
            };
            Some(TestRec { tag, val: rec.get("val").as_f64()? })
        }
    }

    fn cfg() -> StoreConfig {
        StoreConfig {
            schema_version: 7,
            default_shards: 4,
            file_prefix: "t",
            label: "test store",
            policy: StorePolicy::default_auto(),
            codec: Codec::V2Binary,
        }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("fso-sharded-core-{}-{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn open(dir: &Path) -> ShardedStore<TestRec> {
        ShardedStore::open(dir, cfg()).unwrap()
    }

    /// Keys with a chosen top byte (shard) and low tag.
    fn key(top: u8, low: u64) -> u64 {
        ((top as u64) << 56) | low
    }

    fn rec(val: f64) -> TestRec {
        TestRec { tag: "a", val }
    }

    #[test]
    fn roundtrip_kind_mismatch_and_tombstone_semantics() {
        let dir = tmp_dir("roundtrip");
        {
            let s = open(&dir);
            s.put(key(1, 10), rec(0.5));
            s.put(key(1, 11), TestRec { tag: "b", val: 1.5 });
            assert_eq!(s.stats().pending, 2);
            s.flush().unwrap();
            assert_eq!(s.stats().pending, 0);
        }
        let s = open(&dir);
        assert_eq!(s.get("a", key(1, 10)), Some(rec(0.5)));
        assert_eq!(s.get("b", key(1, 10)), None, "kind mismatch is a miss");
        assert_eq!(s.get("b", key(1, 11)), Some(TestRec { tag: "b", val: 1.5 }));
        assert!(s.evict(key(1, 10)));
        assert!(!s.evict(key(1, 10)), "second evict finds nothing live");
        assert_eq!(s.get("a", key(1, 10)), None, "evicted key is a miss");
        s.flush().unwrap();
        drop(s);
        let s = open(&dir);
        assert_eq!(s.get("a", key(1, 10)), None, "tombstone survives reopen");
        assert_eq!(s.get("b", key(1, 11)), Some(TestRec { tag: "b", val: 1.5 }));
        // resurrection: a fresh put over the tombstone is live again
        s.put(key(1, 10), rec(2.5));
        s.flush().unwrap();
        drop(s);
        let s = open(&dir);
        assert_eq!(s.get("a", key(1, 10)), Some(rec(2.5)));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn pending_counts_only_undurable_slots_after_merge_on_flush() {
        // the ISSUE 4 stats-drift fix, at the core level: disk records
        // folded in by merge-on-flush must not count as pending when a
        // new record later dirties their shard
        let dir = tmp_dir("pending");
        {
            let other = open(&dir);
            other.put(key(2, 1), rec(1.0));
            other.put(key(2, 2), rec(2.0));
            other.flush().unwrap();
        }
        let s = open(&dir);
        s.put(key(2, 3), rec(3.0));
        assert_eq!(s.stats().pending, 1);
        s.flush().unwrap(); // merges keys 1 and 2 from disk
        assert_eq!(s.stats().entries, 3);
        assert_eq!(s.stats().pending, 0);
        s.put(key(2, 4), rec(4.0));
        let st = s.stats();
        assert_eq!(st.entries, 4);
        assert_eq!(
            st.pending, 1,
            "pending must count the one new record, not the whole dirty shard"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn byte_budget_evicts_lru_then_compact_fits_files_in_budget() {
        let dir = tmp_dir("budget");
        let n = 10u64;
        let probe_dir = tmp_dir("budget-probe");
        let line_len = {
            // probe one record's serialized size (all identical shape);
            // a byte budget must be set for puts to measure themselves
            let probe = ShardedStore::<TestRec>::open(
                &probe_dir,
                StoreConfig {
                    policy: StorePolicy {
                        max_bytes: Some(u64::MAX),
                        ..StorePolicy::default()
                    },
                    ..cfg()
                },
            )
            .unwrap();
            probe.put(key(3, 100), rec(0.25));
            probe.stats().live_bytes as usize
        };
        let _ = fs::remove_dir_all(&probe_dir);
        let budget = (line_len * 6) as u64; // room for ~6 of 10
        let s = ShardedStore::<TestRec>::open(
            &dir,
            StoreConfig {
                policy: StorePolicy { max_bytes: Some(budget), ..StorePolicy::default() },
                ..cfg()
            },
        )
        .unwrap();
        for i in 0..n {
            s.put(key(3, 100 + i), rec(0.25));
        }
        s.flush().unwrap();
        let st = s.stats();
        assert!(st.evictions > 0, "over-budget store must evict: {st:?}");
        assert!(
            st.live_bytes <= budget,
            "live bytes {} must fit the budget {budget}",
            st.live_bytes
        );
        // same stamp everywhere -> ties break by key: smallest evicted
        assert_eq!(s.get("a", key(3, 100)), None, "oldest (smallest key) evicted");
        assert_eq!(s.get("a", key(3, 100 + n - 1)), Some(rec(0.25)), "newest kept");
        s.compact().unwrap();
        let on_disk: u64 = fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().path())
            .filter(|p| {
                let name = p.file_name().unwrap().to_string_lossy().to_string();
                name.starts_with("t-") && !name.ends_with(".idx")
            })
            .map(|p| fs::metadata(&p).unwrap().len())
            .sum();
        assert!(
            on_disk <= budget,
            "compacted shard files ({on_disk} B) must fit the byte budget ({budget} B)"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_prefers_recently_used_across_epochs() {
        let dir = tmp_dir("lru");
        {
            let s = open(&dir); // epoch 1
            for i in 0..4u64 {
                s.put(key(4, i), rec(i as f64));
            }
            s.flush().unwrap();
        }
        // epoch 2: touch key 2, add key 9, then shrink to 2 records
        let s = ShardedStore::<TestRec>::open(
            &dir,
            StoreConfig {
                policy: StorePolicy { max_records: Some(2), ..StorePolicy::default() },
                ..cfg()
            },
        )
        .unwrap();
        assert_eq!(s.epoch(), 2);
        assert!(s.get("a", key(4, 2)).is_some()); // bump to epoch 2
        s.put(key(4, 9), rec(9.0)); // stamped epoch 2
        s.flush().unwrap();
        assert_eq!(s.stats().entries, 2);
        assert!(s.get("a", key(4, 2)).is_some(), "recently-used key survives");
        assert!(s.get("a", key(4, 9)).is_some(), "fresh key survives");
        assert!(s.get("a", key(4, 0)).is_none(), "stale keys evicted");
        assert!(s.get("a", key(4, 1)).is_none());
        assert!(s.get("a", key(4, 3)).is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn age_bound_evicts_unused_epochs() {
        let dir = tmp_dir("age");
        {
            let s = open(&dir); // epoch 1
            s.put(key(5, 1), rec(1.0));
            s.put(key(5, 2), rec(2.0));
            s.flush().unwrap();
        }
        // epoch 2, max_age 0: anything not used *this* epoch goes
        let s = ShardedStore::<TestRec>::open(
            &dir,
            StoreConfig {
                policy: StorePolicy { max_age_epochs: Some(0), ..StorePolicy::default() },
                ..cfg()
            },
        )
        .unwrap();
        assert!(s.get("a", key(5, 1)).is_some()); // bump to epoch 2
        s.put(key(5, 3), rec(3.0));
        s.flush().unwrap();
        assert!(s.get("a", key(5, 1)).is_some(), "used-this-epoch survives");
        assert!(s.get("a", key(5, 3)).is_some());
        assert!(s.get("a", key(5, 2)).is_none(), "unused-for-an-epoch evicted");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn auto_compaction_reclaims_tombstones_past_ratio() {
        let dir = tmp_dir("autocompact");
        let s = open(&dir); // default_auto: compacts past 50% dead
        for i in 0..4u64 {
            s.put(key(6, i), rec(i as f64));
        }
        s.flush().unwrap();
        for i in 0..3u64 {
            assert!(s.evict(key(6, i)));
        }
        // the flush writes 3 tombstones + 1 live record (75% dead) and
        // must then auto-compact them away
        s.flush().unwrap();
        assert!(s.compactions() >= 1, "auto-compaction must have fired");
        assert_eq!(s.stats().tombstones, 0, "compaction drops tombstones");
        // keys carry top byte 6 -> shard 6 % 4 = 2; v2 frames carry the
        // kind as raw bytes, so a tombstone would leave "tomb" in them
        let body = fs::read(dir.join("t-002.fsb")).unwrap_or_default();
        assert!(
            !body.windows(4).any(|w| w == b"tomb"),
            "no tombstone frames may remain on disk"
        );
        assert!(s.get("a", key(6, 3)).is_some());
        for i in 0..3u64 {
            assert!(s.get("a", key(6, i)).is_none(), "evicted key resurfaced");
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_is_idempotent_and_preserves_reads() {
        let dir = tmp_dir("idempotent");
        let s = open(&dir);
        for i in 0..6u64 {
            s.put(key(7, i), TestRec { tag: if i % 2 == 0 { "a" } else { "b" }, val: i as f64 });
        }
        s.flush().unwrap();
        s.evict(key(7, 0));
        let r1 = s.compact().unwrap();
        assert_eq!(r1.live_records, 5);
        assert_eq!(r1.tombstones_dropped, 1);
        let snapshot: Vec<Option<TestRec>> = (0..6)
            .map(|i| s.get(if i % 2 == 0 { "a" } else { "b" }, key(7, i)))
            .collect();
        let r2 = s.compact().unwrap();
        assert_eq!(r2.shards_rewritten, 0, "second compact must be a no-op");
        assert_eq!(r2.bytes_before, r2.bytes_after);
        let after: Vec<Option<TestRec>> = (0..6)
            .map(|i| s.get(if i % 2 == 0 { "a" } else { "b" }, key(7, i)))
            .collect();
        assert_eq!(snapshot, after, "compaction must not change any read result");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn epoch_bumps_per_open_and_meta_pins_shards() {
        let dir = tmp_dir("epoch");
        {
            let s = ShardedStore::<TestRec>::open_sharded(&dir, cfg(), 2).unwrap();
            assert_eq!(s.epoch(), 1);
            assert_eq!(s.shard_count(), 2);
        }
        let s = ShardedStore::<TestRec>::open_sharded(&dir, cfg(), 64).unwrap();
        assert_eq!(s.epoch(), 2, "every open bumps the logical epoch");
        assert_eq!(s.shard_count(), 2, "meta.json pins the shard count");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn v1_codec_writes_byte_identical_files_to_the_pr6_writer() {
        let dir = tmp_dir("v1bytes");
        let s = ShardedStore::<TestRec>::open(&dir, cfg())
            .unwrap()
            .with_codec(Codec::V1Jsonl);
        s.put(key(1, 0x10), rec(0.5));
        s.flush().unwrap();
        drop(s);
        let text = fs::read_to_string(dir.join("t-001.jsonl")).unwrap();
        assert_eq!(
            text,
            "{\"key\":\"0100000000000010\",\"kind\":\"a\",\"used\":1,\"v\":7,\"val\":0.5}\n",
            "v1 output must stay byte-compatible with dirs written before the codec seam"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn mixed_codec_dirs_auto_detect_and_flush_collapses_to_active() {
        let dir = tmp_dir("mixed");
        {
            let s = ShardedStore::<TestRec>::open(&dir, cfg())
                .unwrap()
                .with_codec(Codec::V1Jsonl);
            for i in 0..3u64 {
                s.put(key(9, i), rec(i as f64));
            }
            s.flush().unwrap();
            assert!(dir.join("t-001.jsonl").exists()); // 9 % 4 = 1
        }
        let s = open(&dir); // active codec v2
        assert_eq!(s.get("a", key(9, 1)), Some(rec(1.0)), "v1 file auto-detected");
        s.put(key(9, 7), rec(7.0));
        s.flush().unwrap();
        assert_eq!(
            s.transcoded_records(),
            2,
            "the two still-lazy v1 frames transcode at the rewrite"
        );
        assert!(dir.join("t-001.fsb").exists(), "flush rewrites under the active codec");
        assert!(!dir.join("t-001.jsonl").exists(), "the v1 file is collapsed away");
        drop(s);
        let s = open(&dir);
        for i in 0..3u64 {
            assert_eq!(s.get("a", key(9, i)), Some(rec(i as f64)));
        }
        assert_eq!(s.get("a", key(9, 7)), Some(rec(7.0)));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn sidecar_point_lookup_skips_scans_and_survives_idx_deletion() {
        let dir = tmp_dir("sidecar");
        {
            let s = open(&dir);
            for i in 0..8u64 {
                s.put(key(8, i), rec(i as f64)); // 8 % 4 = 0
            }
            s.flush().unwrap();
        }
        let s = open(&dir);
        assert_eq!(s.get("a", key(8, 3)), Some(rec(3.0)));
        assert_eq!(s.sidecar_hits(), 1);
        assert_eq!(s.shard_loads(), 0, "a point lookup must not scan the shard");
        assert_eq!(s.full_decodes(), 1, "exactly the fetched frame decodes");
        assert_eq!(s.get("a", key(8, 77)), None);
        assert_eq!(s.sidecar_hits(), 2, "a definitive miss is answered by the index");
        assert_eq!(s.full_decodes(), 1, "a lookup miss costs no full-tree parse");
        assert_eq!(s.shard_loads(), 0);
        drop(s);
        // delete every sidecar: reads fall back to the scan and the
        // store silently re-derives the indexes
        for e in fs::read_dir(&dir).unwrap().flatten() {
            if e.file_name().to_string_lossy().ends_with(".idx") {
                fs::remove_file(e.path()).unwrap();
            }
        }
        let s = open(&dir);
        assert_eq!(s.get("a", key(8, 3)), Some(rec(3.0)));
        assert!(s.shard_loads() >= 1, "missing sidecar falls back to the scan");
        assert!(s.sidecar_rebuilds() >= 1, "missing sidecar is rebuilt");
        assert!(
            idx_path(&dir.join("t-000.fsb")).exists(),
            "the sidecar file is recreated on disk"
        );
        let _ = fs::remove_dir_all(&dir);
    }
}
