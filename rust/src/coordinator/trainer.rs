//! Model training orchestration (paper §7.3): for a (dataset, split,
//! metric), fit the two-stage ROI classifier plus all five regressor
//! families — GBDT / RF (tuned by random discrete search), ANN / GCN
//! (AOT artifacts through the PJRT engine), and the stacked ensemble —
//! and evaluate muAPE / MAPE / STD APE on the test rows the ROI gate
//! accepts.
//!
//! Persistence (ISSUE 3): with a [`ModelStore`] attached, every tree-
//! family fit request reads through the store — a warm start at the
//! same (data, budget, seed) skips the tuning searches entirely and
//! replays bit-identical predictions — and freshly fitted models are
//! written behind (durable at the caller's flush). The per-run
//! [`ModelCacheStats`] in each report pin the acceptance contract:
//! a warm rerun shows 0 refits and 0 tuning-search evaluations.
//!
//! Since ISSUE 4 the store is a thin wrapper over the shared
//! `coordinator::store` core, which may evict cold artifacts under a
//! configured budget and compact its shards (`fso store compact`):
//! both are invisible here beyond extra refits for evicted keys — a
//! stored artifact that survives replays bit-identically, and a
//! missing one falls back to the plain fit path below.

use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::data::{Dataset, Metric, Split};
use crate::metrics::{mape_stats, ClassifyStats, MapeStats};
use crate::models::{
    tune_gbdt, tune_rf, AnnModel, BasePredictions, GcnModel, GraphCache, RoiClassifier,
    SearchBudget, StackedEnsemble, TrainConfig, TunedGbdt, TunedRf,
};
use crate::runtime::Engine;

use super::model_store::{ModelKey, ModelStore};

/// Which model families to run (GCN/ANN dominate wall-clock; experiments
/// can trim).
#[derive(Debug, Clone, Copy)]
pub struct ModelMenu {
    pub gbdt: bool,
    pub rf: bool,
    pub ann: bool,
    pub ensemble: bool,
    pub gcn: bool,
}

impl Default for ModelMenu {
    fn default() -> Self {
        ModelMenu { gbdt: true, rf: true, ann: true, ensemble: true, gcn: true }
    }
}

impl ModelMenu {
    pub fn trees_only() -> Self {
        ModelMenu { gbdt: true, rf: true, ann: false, ensemble: false, gcn: false }
    }
}

#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub menu: ModelMenu,
    pub search: SearchBudget,
    pub ann_cfg: TrainConfig,
    pub gcn_cfg: TrainConfig,
    pub ann_variant: String,
    pub gcn_variant: String,
    pub seed: u64,
    /// Parallelism switch for the tree-family tuners: any value > 1
    /// (or 0 = auto, when more than one core is available) runs the
    /// GBDT and RF searches concurrently; 1 forces the serial order.
    /// Results are seed-determined and identical either way — only
    /// wall-clock changes.
    pub workers: usize,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            menu: ModelMenu::default(),
            search: SearchBudget::default(),
            ann_cfg: TrainConfig::default(),
            gcn_cfg: TrainConfig {
                max_epochs: 40,
                lr0: 8e-3,
                early_stop: 10,
                patience: 4,
                ..Default::default()
            },
            ann_variant: "ann32x4_relu".to_string(),
            gcn_variant: "gcn3".to_string(),
            seed: 7,
            workers: 0,
        }
    }
}

impl TrainOptions {
    fn effective_workers(&self) -> usize {
        if self.workers == 0 {
            crate::util::pool::default_workers()
        } else {
            self.workers
        }
    }
}

/// Per-run model-cache accounting (ISSUE 3 acceptance: a warm rerun
/// reports 0 refits and 0 tuning-search evaluations).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ModelCacheStats {
    /// Models served from the persistent store (bit-identical replay).
    pub cached: usize,
    /// Models fitted fresh this run.
    pub refits: usize,
    /// Tuning-search model evaluations executed (stage-1 + stage-2
    /// fits per random discrete search that actually ran).
    pub tuning_evals: usize,
}

impl std::ops::AddAssign for ModelCacheStats {
    fn add_assign(&mut self, o: ModelCacheStats) {
        self.cached += o.cached;
        self.refits += o.refits;
        self.tuning_evals += o.tuning_evals;
    }
}

impl std::fmt::Display for ModelCacheStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} models cached | {} refits | {} tuning evals",
            self.cached, self.refits, self.tuning_evals
        )
    }
}

/// Per-model evaluation on the ROI-gated test set.
#[derive(Debug, Clone)]
pub struct EvalReport {
    pub metric: Metric,
    pub roi: ClassifyStats,
    /// model name -> stats on accepted test rows
    pub models: BTreeMap<String, MapeStats>,
    /// test rows accepted by the ROI gate (and actually in ROI)
    pub eval_rows: usize,
    /// How this run's models were obtained (store hits vs. refits).
    pub model_cache: ModelCacheStats,
}

pub struct Trainer {
    pub engine: Option<Rc<Engine>>,
    /// Optional persistent surrogate-model store: fit requests read
    /// through it, fresh fits are written behind (ISSUE 3).
    pub model_store: Option<Arc<ModelStore>>,
    /// In-process fit memo (`--coalesce`, ISSUE 5): identical fit
    /// requests — same family kind and content-hash key — are served
    /// from memory after the first fit, sharing one tuning search
    /// across metrics and repeated runs even without a persistent
    /// store (the ROI classifier, for one, is metric-independent and
    /// would otherwise refit once per metric). Artifacts replay
    /// bit-identically, so results never change; a memo hit counts as
    /// `cached` in [`ModelCacheStats`].
    fit_memo: Option<Mutex<HashMap<(String, u64), crate::util::json::Json>>>,
}

impl Trainer {
    /// `engine` is optional: tree-only menus never touch PJRT.
    pub fn new(engine: Option<Rc<Engine>>) -> Trainer {
        Trainer { engine, model_store: None, fit_memo: None }
    }

    pub fn from_artifacts() -> Result<Trainer> {
        let dir = crate::test_support::artifacts_dir()
            .context("artifacts not found (run `make artifacts`)")?;
        Ok(Trainer::new(Some(Rc::new(Engine::load(&dir)?))))
    }

    /// Attach a persistent model store (read-through on fit requests,
    /// write-behind after tuning). Never changes results — a stored
    /// model replays bit-identical predictions — only wall-clock.
    pub fn with_model_store(mut self, store: Arc<ModelStore>) -> Trainer {
        self.model_store = Some(store);
        self
    }

    /// `with_model_store` for CLI plumbing that may or may not have a
    /// cache dir: attaches when given, no-op otherwise.
    pub fn with_model_store_opt(self, store: Option<Arc<ModelStore>>) -> Trainer {
        match store {
            Some(s) => self.with_model_store(s),
            None => self,
        }
    }

    /// Enable the in-process fit memo (ISSUE 5): repeated identical
    /// fit requests within this trainer's lifetime are served from
    /// memory — zero refits, zero tuning searches — instead of going
    /// back to the store (or refitting when no store is attached).
    /// Never changes results, only wall-clock.
    pub fn with_fit_coalescing(mut self) -> Trainer {
        self.fit_memo = Some(Mutex::new(HashMap::new()));
        self
    }

    /// `with_fit_coalescing` for CLI plumbing (`--coalesce`).
    pub fn with_fit_coalescing_opt(self, on: bool) -> Trainer {
        if on {
            self.with_fit_coalescing()
        } else {
            self
        }
    }

    fn memo_put(&self, kind: &str, key: u64, payload: &crate::util::json::Json) {
        if let Some(memo) = &self.fit_memo {
            memo.lock().unwrap().insert((kind.to_string(), key), payload.clone());
        }
    }

    /// Look up a stored artifact — fit memo first, then the persistent
    /// store — and decode it; a decode failure reads as a miss
    /// (corrupt artifacts fall back to refitting).
    fn load_model<T>(&self, kind: &str, key: u64, decode: impl Fn(&crate::util::json::Json) -> Option<T>) -> Option<T> {
        if let Some(memo) = &self.fit_memo {
            if let Some(payload) = memo.lock().unwrap().get(&(kind.to_string(), key)) {
                if let Some(model) = decode(payload) {
                    return Some(model);
                }
            }
        }
        let payload = self.model_store.as_ref().and_then(|s| s.get(kind, key))?;
        let model = decode(&payload)?;
        self.memo_put(kind, key, &payload);
        Some(model)
    }

    fn store_model(&self, kind: &str, key: u64, payload: crate::util::json::Json) {
        self.memo_put(kind, key, &payload);
        if let Some(store) = &self.model_store {
            store.put(kind, key, payload);
        }
    }

    /// Train + evaluate every family in the menu for one metric.
    ///
    /// Protocol (paper §5.4, §7.2/7.3): ROI classifier fits on all
    /// training rows; regressors fit on ROI training rows only; a
    /// validation subset of the training rows drives tuning/early-stop;
    /// evaluation uses test rows that the classifier accepts and that
    /// are truly in the ROI (discarded rows are dropped, as the paper
    /// does).
    pub fn run(
        &self,
        ds: &Dataset,
        split: &Split,
        metric: Metric,
        opts: &TrainOptions,
    ) -> Result<EvalReport> {
        let mut split = split.clone();
        if split.val.is_empty() {
            ds.carve_validation(&mut split, 0.2, opts.seed);
        }

        let mut mc = ModelCacheStats::default();

        // ---- stage 1: ROI classifier on all training rows ----
        let x_all_train = ds.features(&split.train);
        let roi_train = ds.roi_labels(&split.train);
        let cls_key = ModelKey::new("roi-classifier")
            .rows(&x_all_train)
            .bools(&roi_train)
            .u64(opts.seed)
            .finish();
        let classifier =
            match self.load_model("roi-classifier", cls_key, RoiClassifier::from_json) {
                Some(c) => {
                    mc.cached += 1;
                    c
                }
                None => {
                    let c = RoiClassifier::fit(&x_all_train, &roi_train, opts.seed);
                    mc.refits += 1;
                    self.store_model("roi-classifier", cls_key, c.to_json());
                    c
                }
            };
        let x_test = ds.features(&split.test);
        let roi_test = ds.roi_labels(&split.test);
        let roi_stats = classifier.evaluate(&x_test, &roi_test);

        // accepted = classifier-accepted AND truly in ROI
        let accept = classifier.predict(&x_test);
        let eval_idx: Vec<usize> = split
            .test
            .iter()
            .enumerate()
            .filter(|(k, &i)| accept[*k] && ds.rows[i].in_roi)
            .map(|(_, &i)| i)
            .collect();

        // ---- stage 2: regressors on ROI training rows ----
        let train_roi = ds.roi_subset(&split.train);
        let val_roi = ds.roi_subset(&split.val);
        anyhow::ensure!(!train_roi.is_empty(), "no ROI training rows");
        anyhow::ensure!(!val_roi.is_empty(), "no ROI validation rows");
        let x_train = ds.features(&train_roi);
        let y_train = ds.targets(&train_roi, metric);
        let x_val = ds.features(&val_roi);
        let y_val = ds.targets(&val_roi, metric);
        let x_eval = ds.features(&eval_idx);
        let y_eval = ds.targets(&eval_idx, metric);

        let mut models = BTreeMap::new();
        let mut bases: Vec<BasePredictions> = Vec::new();

        // tuned-model keys: the search is a pure function of the four
        // matrices and the budget, so these cover dataset, split,
        // metric, tuning config, and seed at once
        let tuner_key = |tag: &str| {
            ModelKey::new(tag)
                .rows(&x_train)
                .f64s(&y_train)
                .rows(&x_val)
                .f64s(&y_val)
                .usize(opts.search.stage1)
                .usize(opts.search.stage2)
                .u64(opts.search.seed)
                .finish()
        };
        let gbdt_key = tuner_key("tuned-gbdt");
        let rf_key = tuner_key("tuned-rf");
        let cached_gbdt = opts
            .menu
            .gbdt
            .then(|| self.load_model("tuned-gbdt", gbdt_key, TunedGbdt::from_json))
            .flatten();
        let cached_rf = opts
            .menu
            .rf
            .then(|| self.load_model("tuned-rf", rf_key, TunedRf::from_json))
            .flatten();

        // the GBDT and RF tuners are independent seeded searches: when
        // both actually need to run, fan them out on the shared pool
        // (same EvalService discipline — parallelism never changes
        // seeded results); a store hit skips its search entirely
        let need_g = opts.menu.gbdt && cached_gbdt.is_none();
        let need_r = opts.menu.rf && cached_rf.is_none();
        let (fresh_gbdt, fresh_rf) = if need_g && need_r && opts.effective_workers() > 1 {
            std::thread::scope(|scope| {
                let g = scope
                    .spawn(|| tune_gbdt(&x_train, &y_train, &x_val, &y_val, opts.search));
                let r = scope
                    .spawn(|| tune_rf(&x_train, &y_train, &x_val, &y_val, opts.search));
                (
                    Some(g.join().expect("gbdt tuner panicked")),
                    Some(r.join().expect("rf tuner panicked")),
                )
            })
        } else {
            (
                need_g.then(|| tune_gbdt(&x_train, &y_train, &x_val, &y_val, opts.search)),
                need_r.then(|| tune_rf(&x_train, &y_train, &x_val, &y_val, opts.search)),
            )
        };
        let search_evals = opts.search.stage1 + opts.search.stage2;
        let tuned_gbdt = match (cached_gbdt, fresh_gbdt) {
            (Some(t), _) => {
                mc.cached += 1;
                Some(t)
            }
            (None, Some(t)) => {
                mc.refits += 1;
                mc.tuning_evals += search_evals;
                self.store_model("tuned-gbdt", gbdt_key, t.to_json());
                Some(t)
            }
            (None, None) => None,
        };
        let tuned_rf = match (cached_rf, fresh_rf) {
            (Some(t), _) => {
                mc.cached += 1;
                Some(t)
            }
            (None, Some(t)) => {
                mc.refits += 1;
                mc.tuning_evals += search_evals;
                self.store_model("tuned-rf", rf_key, t.to_json());
                Some(t)
            }
            (None, None) => None,
        };

        if let Some(tuned) = tuned_gbdt {
            let pred = tuned.model.predict(&x_eval);
            models.insert("GBDT".to_string(), mape_stats(&y_eval, &pred));
            bases.push(BasePredictions {
                name: "GBDT".into(),
                val: tuned.model.predict(&x_val),
                test: pred,
            });
        }
        if let Some(tuned) = tuned_rf {
            let pred = tuned.model.predict(&x_eval);
            models.insert("RF".to_string(), mape_stats(&y_eval, &pred));
            bases.push(BasePredictions {
                name: "RF".into(),
                val: tuned.model.predict(&x_val),
                test: pred,
            });
        }
        if opts.menu.ann {
            let engine = self.engine.as_ref().context("ANN needs the PJRT engine")?;
            let mut ann = AnnModel::new(engine.clone(), &opts.ann_variant, opts.ann_cfg)?;
            ann.fit(&x_train, &y_train, &x_val, &y_val)?;
            mc.refits += 1; // PJRT models are not persisted (AOT theta lives elsewhere)
            let pred = ann.predict(&x_eval)?;
            models.insert("ANN".to_string(), mape_stats(&y_eval, &pred));
            bases.push(BasePredictions {
                name: "ANN".into(),
                val: ann.predict(&x_val)?,
                test: pred,
            });
        }
        if opts.menu.ensemble && bases.len() >= 2 {
            // keyed by what the meta-learner sees: base names + their
            // validation predictions + the validation targets
            let mut ekey = ModelKey::new("stacked-ensemble");
            for b in &bases {
                ekey = ekey.str(&b.name).f64s(&b.val);
            }
            let ens_key = ekey.f64s(&y_val).finish();
            let ens = match self.load_model("stacked-ensemble", ens_key, StackedEnsemble::from_json)
            {
                Some(e) => {
                    mc.cached += 1;
                    e
                }
                None => {
                    let e = StackedEnsemble::fit(&bases, &y_val)?;
                    mc.refits += 1;
                    self.store_model("stacked-ensemble", ens_key, e.to_json());
                    e
                }
            };
            let pred = ens.predict(&bases);
            models.insert("Ensemble".to_string(), mape_stats(&y_eval, &pred));
        }
        if opts.menu.gcn {
            let engine = self.engine.as_ref().context("GCN needs the PJRT engine")?;
            let cache = GraphCache::build(&ds.lhgs, engine.manifest.nodes)?;
            let mut gcn = GcnModel::new(engine.clone(), &opts.gcn_variant, opts.gcn_cfg)?;
            let targets: Vec<f64> = ds.rows.iter().map(|r| r.target(metric)).collect();
            gcn.fit(ds, &cache, &train_roi, &val_roi, &targets)?;
            mc.refits += 1;
            let pred = gcn.predict_rows(ds, &cache, &eval_idx)?;
            models.insert("GCN".to_string(), mape_stats(&y_eval, &pred));
        }

        Ok(EvalReport {
            metric,
            roi: roi_stats,
            models,
            eval_rows: eval_idx.len(),
            model_cache: mc,
        })
    }
}
