//! Model training orchestration (paper §7.3): for a (dataset, split,
//! metric), fit the two-stage ROI classifier plus all five regressor
//! families — GBDT / RF (tuned by random discrete search), ANN / GCN
//! (AOT artifacts through the PJRT engine), and the stacked ensemble —
//! and evaluate muAPE / MAPE / STD APE on the test rows the ROI gate
//! accepts.

use std::collections::BTreeMap;
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::data::{Dataset, Metric, Split};
use crate::metrics::{mape_stats, ClassifyStats, MapeStats};
use crate::models::{
    tune_gbdt, tune_rf, AnnModel, BasePredictions, GcnModel, GraphCache, RoiClassifier,
    SearchBudget, StackedEnsemble, TrainConfig,
};
use crate::runtime::Engine;

/// Which model families to run (GCN/ANN dominate wall-clock; experiments
/// can trim).
#[derive(Debug, Clone, Copy)]
pub struct ModelMenu {
    pub gbdt: bool,
    pub rf: bool,
    pub ann: bool,
    pub ensemble: bool,
    pub gcn: bool,
}

impl Default for ModelMenu {
    fn default() -> Self {
        ModelMenu { gbdt: true, rf: true, ann: true, ensemble: true, gcn: true }
    }
}

impl ModelMenu {
    pub fn trees_only() -> Self {
        ModelMenu { gbdt: true, rf: true, ann: false, ensemble: false, gcn: false }
    }
}

#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub menu: ModelMenu,
    pub search: SearchBudget,
    pub ann_cfg: TrainConfig,
    pub gcn_cfg: TrainConfig,
    pub ann_variant: String,
    pub gcn_variant: String,
    pub seed: u64,
    /// Parallelism switch for the tree-family tuners: any value > 1
    /// (or 0 = auto, when more than one core is available) runs the
    /// GBDT and RF searches concurrently; 1 forces the serial order.
    /// Results are seed-determined and identical either way — only
    /// wall-clock changes.
    pub workers: usize,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            menu: ModelMenu::default(),
            search: SearchBudget::default(),
            ann_cfg: TrainConfig::default(),
            gcn_cfg: TrainConfig {
                max_epochs: 40,
                lr0: 8e-3,
                early_stop: 10,
                patience: 4,
                ..Default::default()
            },
            ann_variant: "ann32x4_relu".to_string(),
            gcn_variant: "gcn3".to_string(),
            seed: 7,
            workers: 0,
        }
    }
}

impl TrainOptions {
    fn effective_workers(&self) -> usize {
        if self.workers == 0 {
            crate::util::pool::default_workers()
        } else {
            self.workers
        }
    }
}

/// Per-model evaluation on the ROI-gated test set.
#[derive(Debug, Clone)]
pub struct EvalReport {
    pub metric: Metric,
    pub roi: ClassifyStats,
    /// model name -> stats on accepted test rows
    pub models: BTreeMap<String, MapeStats>,
    /// test rows accepted by the ROI gate (and actually in ROI)
    pub eval_rows: usize,
}

pub struct Trainer {
    pub engine: Option<Rc<Engine>>,
}

impl Trainer {
    /// `engine` is optional: tree-only menus never touch PJRT.
    pub fn new(engine: Option<Rc<Engine>>) -> Trainer {
        Trainer { engine }
    }

    pub fn from_artifacts() -> Result<Trainer> {
        let dir = crate::test_support::artifacts_dir()
            .context("artifacts not found (run `make artifacts`)")?;
        Ok(Trainer { engine: Some(Rc::new(Engine::load(&dir)?)) })
    }

    /// Train + evaluate every family in the menu for one metric.
    ///
    /// Protocol (paper §5.4, §7.2/7.3): ROI classifier fits on all
    /// training rows; regressors fit on ROI training rows only; a
    /// validation subset of the training rows drives tuning/early-stop;
    /// evaluation uses test rows that the classifier accepts and that
    /// are truly in the ROI (discarded rows are dropped, as the paper
    /// does).
    pub fn run(
        &self,
        ds: &Dataset,
        split: &Split,
        metric: Metric,
        opts: &TrainOptions,
    ) -> Result<EvalReport> {
        let mut split = split.clone();
        if split.val.is_empty() {
            ds.carve_validation(&mut split, 0.2, opts.seed);
        }

        // ---- stage 1: ROI classifier on all training rows ----
        let x_all_train = ds.features(&split.train);
        let roi_train = ds.roi_labels(&split.train);
        let classifier = RoiClassifier::fit(&x_all_train, &roi_train, opts.seed);
        let x_test = ds.features(&split.test);
        let roi_test = ds.roi_labels(&split.test);
        let roi_stats = classifier.evaluate(&x_test, &roi_test);

        // accepted = classifier-accepted AND truly in ROI
        let accept = classifier.predict(&x_test);
        let eval_idx: Vec<usize> = split
            .test
            .iter()
            .enumerate()
            .filter(|(k, &i)| accept[*k] && ds.rows[i].in_roi)
            .map(|(_, &i)| i)
            .collect();

        // ---- stage 2: regressors on ROI training rows ----
        let train_roi = ds.roi_subset(&split.train);
        let val_roi = ds.roi_subset(&split.val);
        anyhow::ensure!(!train_roi.is_empty(), "no ROI training rows");
        anyhow::ensure!(!val_roi.is_empty(), "no ROI validation rows");
        let x_train = ds.features(&train_roi);
        let y_train = ds.targets(&train_roi, metric);
        let x_val = ds.features(&val_roi);
        let y_val = ds.targets(&val_roi, metric);
        let x_eval = ds.features(&eval_idx);
        let y_eval = ds.targets(&eval_idx, metric);

        let mut models = BTreeMap::new();
        let mut bases: Vec<BasePredictions> = Vec::new();

        // the GBDT and RF tuners are independent seeded searches: run
        // them concurrently on the shared pool (same EvalService
        // discipline — parallelism never changes seeded results)
        let (tuned_gbdt, tuned_rf) =
            if opts.menu.gbdt && opts.menu.rf && opts.effective_workers() > 1 {
                std::thread::scope(|scope| {
                    let g = scope
                        .spawn(|| tune_gbdt(&x_train, &y_train, &x_val, &y_val, opts.search));
                    let r = scope
                        .spawn(|| tune_rf(&x_train, &y_train, &x_val, &y_val, opts.search));
                    (
                        Some(g.join().expect("gbdt tuner panicked")),
                        Some(r.join().expect("rf tuner panicked")),
                    )
                })
            } else {
                (
                    opts.menu
                        .gbdt
                        .then(|| tune_gbdt(&x_train, &y_train, &x_val, &y_val, opts.search)),
                    opts.menu
                        .rf
                        .then(|| tune_rf(&x_train, &y_train, &x_val, &y_val, opts.search)),
                )
            };

        if let Some(tuned) = tuned_gbdt {
            let pred = tuned.model.predict(&x_eval);
            models.insert("GBDT".to_string(), mape_stats(&y_eval, &pred));
            bases.push(BasePredictions {
                name: "GBDT".into(),
                val: tuned.model.predict(&x_val),
                test: pred,
            });
        }
        if let Some(tuned) = tuned_rf {
            let pred = tuned.model.predict(&x_eval);
            models.insert("RF".to_string(), mape_stats(&y_eval, &pred));
            bases.push(BasePredictions {
                name: "RF".into(),
                val: tuned.model.predict(&x_val),
                test: pred,
            });
        }
        if opts.menu.ann {
            let engine = self.engine.as_ref().context("ANN needs the PJRT engine")?;
            let mut ann = AnnModel::new(engine.clone(), &opts.ann_variant, opts.ann_cfg)?;
            ann.fit(&x_train, &y_train, &x_val, &y_val)?;
            let pred = ann.predict(&x_eval)?;
            models.insert("ANN".to_string(), mape_stats(&y_eval, &pred));
            bases.push(BasePredictions {
                name: "ANN".into(),
                val: ann.predict(&x_val)?,
                test: pred,
            });
        }
        if opts.menu.ensemble && bases.len() >= 2 {
            let ens = StackedEnsemble::fit(&bases, &y_val)?;
            let pred = ens.predict(&bases);
            models.insert("Ensemble".to_string(), mape_stats(&y_eval, &pred));
        }
        if opts.menu.gcn {
            let engine = self.engine.as_ref().context("GCN needs the PJRT engine")?;
            let cache = GraphCache::build(&ds.lhgs, engine.manifest.nodes)?;
            let mut gcn = GcnModel::new(engine.clone(), &opts.gcn_variant, opts.gcn_cfg)?;
            let targets: Vec<f64> = ds.rows.iter().map(|r| r.target(metric)).collect();
            gcn.fit(ds, &cache, &train_roi, &val_roi, &targets)?;
            let pred = gcn.predict_rows(ds, &cache, &eval_idx)?;
            models.insert("GCN".to_string(), mape_stats(&y_eval, &pred));
        }

        Ok(EvalReport { metric, roi: roi_stats, models, eval_rows: eval_idx.len() })
    }
}
