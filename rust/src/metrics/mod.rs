//! Evaluation metrics (paper §7.3/§8): muAPE, MAPE (max APE), STD APE,
//! RMSE, Kendall rank correlation (Fig. 1b), and binary classification
//! accuracy/F1 for the ROI classifier.

/// Absolute percentage errors, in percent.
pub fn ape(actual: &[f64], pred: &[f64]) -> Vec<f64> {
    actual
        .iter()
        .zip(pred.iter())
        .map(|(a, p)| (a - p).abs() / a.abs().max(1e-12) * 100.0)
        .collect()
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MapeStats {
    /// Mean absolute percentage error (paper Eq. 7), %.
    pub mu_ape: f64,
    /// Maximum absolute percentage error, %.
    pub max_ape: f64,
    /// Standard deviation of APE, %.
    pub std_ape: f64,
}

pub fn mape_stats(actual: &[f64], pred: &[f64]) -> MapeStats {
    assert_eq!(actual.len(), pred.len());
    if actual.is_empty() {
        return MapeStats { mu_ape: f64::NAN, max_ape: f64::NAN, std_ape: f64::NAN };
    }
    let apes = ape(actual, pred);
    let n = apes.len() as f64;
    let mu = apes.iter().sum::<f64>() / n;
    let max = apes.iter().fold(0.0f64, |a, &b| a.max(b));
    let var = apes.iter().map(|a| (a - mu) * (a - mu)).sum::<f64>() / n;
    MapeStats { mu_ape: mu, max_ape: max, std_ape: var.sqrt() }
}

pub fn rmse(actual: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(actual.len(), pred.len());
    let n = actual.len().max(1) as f64;
    (actual
        .iter()
        .zip(pred.iter())
        .map(|(a, p)| (a - p) * (a - p))
        .sum::<f64>()
        / n)
        .sqrt()
}

/// Kendall rank correlation coefficient tau-a (paper Fig. 1b): fraction
/// of concordant minus discordant pairs.
pub fn kendall_tau(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    if n < 2 {
        return 0.0;
    }
    let mut concordant = 0i64;
    let mut discordant = 0i64;
    for i in 0..n {
        for j in (i + 1)..n {
            let sx = (x[i] - x[j]).signum();
            let sy = (y[i] - y[j]).signum();
            let s = sx * sy;
            if s > 0.0 {
                concordant += 1;
            } else if s < 0.0 {
                discordant += 1;
            }
        }
    }
    let pairs = (n * (n - 1) / 2) as f64;
    (concordant - discordant) as f64 / pairs
}

/// Binary classification report for the ROI classifier (§8.2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClassifyStats {
    pub accuracy: f64,
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
}

pub fn classify_stats(actual: &[bool], pred: &[bool]) -> ClassifyStats {
    assert_eq!(actual.len(), pred.len());
    let (mut tp, mut tn, mut fp, mut fne) = (0.0, 0.0, 0.0, 0.0);
    for (&a, &p) in actual.iter().zip(pred.iter()) {
        match (a, p) {
            (true, true) => tp += 1.0,
            (false, false) => tn += 1.0,
            (false, true) => fp += 1.0,
            (true, false) => fne += 1.0,
        }
    }
    let n = actual.len().max(1) as f64;
    let accuracy = (tp + tn) / n;
    let precision = if tp + fp > 0.0 { tp / (tp + fp) } else { 1.0 };
    let recall = if tp + fne > 0.0 { tp / (tp + fne) } else { 1.0 };
    let f1 = if precision + recall > 0.0 {
        2.0 * precision * recall / (precision + recall)
    } else {
        0.0
    };
    ClassifyStats { accuracy, precision, recall, f1 }
}

/// R^2 coefficient of determination (used by related-work comparisons).
pub fn r_squared(actual: &[f64], pred: &[f64]) -> f64 {
    let n = actual.len() as f64;
    let mean = actual.iter().sum::<f64>() / n;
    let ss_tot: f64 = actual.iter().map(|a| (a - mean) * (a - mean)).sum();
    let ss_res: f64 = actual
        .iter()
        .zip(pred.iter())
        .map(|(a, p)| (a - p) * (a - p))
        .sum();
    1.0 - ss_res / ss_tot.max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mape_hand_computed() {
        let s = mape_stats(&[100.0, 200.0, 50.0], &[110.0, 180.0, 50.0]);
        assert!((s.mu_ape - (10.0 + 10.0 + 0.0) / 3.0).abs() < 1e-9);
        assert!((s.max_ape - 10.0).abs() < 1e-9);
    }

    #[test]
    fn perfect_prediction_is_zero_error() {
        let y = [1.0, 2.0, 3.0];
        let s = mape_stats(&y, &y);
        assert_eq!(s.mu_ape, 0.0);
        assert_eq!(s.max_ape, 0.0);
        assert_eq!(s.std_ape, 0.0);
        assert_eq!(rmse(&y, &y), 0.0);
        assert!((r_squared(&y, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn kendall_extremes() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let up = [10.0, 20.0, 30.0, 40.0];
        let down = [4.0, 3.0, 2.0, 1.0];
        assert_eq!(kendall_tau(&x, &up), 1.0);
        assert_eq!(kendall_tau(&x, &down), -1.0);
    }

    #[test]
    fn kendall_mixed() {
        // one discordant pair out of three: tau = (2-1)/3
        let x = [1.0, 2.0, 3.0];
        let y = [1.0, 3.0, 2.0];
        assert!((kendall_tau(&x, &y) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn classify_hand_computed() {
        let actual = [true, true, false, false, true];
        let pred = [true, false, false, true, true];
        let s = classify_stats(&actual, &pred);
        assert!((s.accuracy - 0.6).abs() < 1e-12);
        assert!((s.precision - 2.0 / 3.0).abs() < 1e-12);
        assert!((s.recall - 2.0 / 3.0).abs() < 1e-12);
        assert!((s.f1 - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn rmse_hand_computed() {
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }
}
