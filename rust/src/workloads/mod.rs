//! Workload descriptions (paper §3: cost metrics depend on network
//! topology, not input data). DNN layer tables (ResNet-50,
//! MobileNet-v1, a transformer encoder, a GCN) drive the DNN
//! simulators (GeneSys, VTA); the non-DNN algorithm specs drive TABLA
//! and Axiline.
//!
//! Every runnable workload is addressable by name through the
//! [`lookup`] registry — the single home of workload-name resolution
//! (the `--workload` CLI axis); unknown names error with the full
//! list instead of silently defaulting.

pub mod gcn;
pub mod mobilenet;
pub mod resnet50;
pub mod transformer;

pub use gcn::gcn_two_layer;
pub use mobilenet::mobilenet_v1;
pub use resnet50::resnet50;
pub use transformer::transformer_encoder;

use anyhow::{bail, Result};

/// One DNN layer as the simulators see it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Layer {
    /// Convolution: input H x W x Cin, K x K kernel, Cout filters.
    Conv { h: usize, w: usize, cin: usize, cout: usize, k: usize, stride: usize },
    /// Depthwise convolution (per-channel K x K).
    DwConv { h: usize, w: usize, c: usize, k: usize, stride: usize },
    /// Fully connected.
    Dense { cin: usize, cout: usize },
    /// Global/strided pooling over H x W x C.
    Pool { h: usize, w: usize, c: usize, k: usize, stride: usize },
    /// Elementwise activation over N values (ReLU etc.).
    Act { n: usize },
    /// Plain matrix multiply (M x K) · (K x N) — the attention /
    /// transformer building block. The right-hand operand is treated
    /// as resident weights (exact for projection/FFN matmuls; for
    /// activation-activation products like QKᵀ the K·N "weights" term
    /// is negligible next to the M·K input traffic).
    MatMul { m: usize, k: usize, n: usize },
}

impl Layer {
    /// Output spatial size of a conv-like layer (same padding).
    fn out_hw(h: usize, w: usize, stride: usize) -> (usize, usize) {
        (h.div_ceil(stride), w.div_ceil(stride))
    }

    /// Multiply-accumulate count.
    pub fn macs(&self) -> u64 {
        match *self {
            Layer::Conv { h, w, cin, cout, k, stride } => {
                let (oh, ow) = Self::out_hw(h, w, stride);
                (oh * ow) as u64 * (k * k * cin) as u64 * cout as u64
            }
            Layer::DwConv { h, w, c, k, stride } => {
                let (oh, ow) = Self::out_hw(h, w, stride);
                (oh * ow) as u64 * (k * k) as u64 * c as u64
            }
            Layer::Dense { cin, cout } => (cin * cout) as u64,
            Layer::MatMul { m, k, n } => (m * k) as u64 * n as u64,
            Layer::Pool { .. } | Layer::Act { .. } => 0,
        }
    }

    /// Vector (non-MAC) op count: pooling reads + activations.
    pub fn vector_ops(&self) -> u64 {
        match *self {
            Layer::Pool { h, w, c, k, stride } => {
                let (oh, ow) = Self::out_hw(h, w, stride);
                (oh * ow * c) as u64 * (k * k) as u64
            }
            Layer::Act { n } => n as u64,
            Layer::Conv { h, w, cout, stride, .. } => {
                // fused bias+ReLU on outputs
                let (oh, ow) = Self::out_hw(h, w, stride);
                (oh * ow * cout) as u64
            }
            Layer::DwConv { h, w, c, stride, .. } => {
                let (oh, ow) = Self::out_hw(h, w, stride);
                (oh * ow * c) as u64
            }
            Layer::Dense { cout, .. } => cout as u64,
            // fused bias/residual epilogue on outputs (Conv convention)
            Layer::MatMul { m, n, .. } => (m * n) as u64,
        }
    }

    /// Weight parameter count.
    pub fn weights(&self) -> u64 {
        match *self {
            Layer::Conv { cin, cout, k, .. } => (k * k * cin * cout) as u64,
            Layer::DwConv { c, k, .. } => (k * k * c) as u64,
            Layer::Dense { cin, cout } => (cin * cout) as u64,
            Layer::MatMul { k, n, .. } => (k * n) as u64,
            Layer::Pool { .. } | Layer::Act { .. } => 0,
        }
    }

    /// Input activation element count.
    pub fn input_elems(&self) -> u64 {
        match *self {
            Layer::Conv { h, w, cin, .. } => (h * w * cin) as u64,
            Layer::DwConv { h, w, c, .. } => (h * w * c) as u64,
            Layer::Dense { cin, .. } => cin as u64,
            Layer::MatMul { m, k, .. } => (m * k) as u64,
            Layer::Pool { h, w, c, .. } => (h * w * c) as u64,
            Layer::Act { n } => n as u64,
        }
    }

    /// Output activation element count.
    pub fn output_elems(&self) -> u64 {
        match *self {
            Layer::Conv { h, w, cout, stride, .. } => {
                let (oh, ow) = Self::out_hw(h, w, stride);
                (oh * ow * cout) as u64
            }
            Layer::DwConv { h, w, c, stride, .. } => {
                let (oh, ow) = Self::out_hw(h, w, stride);
                (oh * ow * c) as u64
            }
            Layer::Dense { cout, .. } => cout as u64,
            Layer::MatMul { m, n, .. } => (m * n) as u64,
            Layer::Pool { h, w, c, k: _, stride } => {
                let (oh, ow) = Self::out_hw(h, w, stride);
                (oh * ow * c) as u64
            }
            Layer::Act { n } => n as u64,
        }
    }

    /// As a GEMM (M, K, N): output-pixels x reduction x filters.
    pub fn as_gemm(&self) -> Option<(u64, u64, u64)> {
        match *self {
            Layer::Conv { h, w, cin, cout, k, stride } => {
                let (oh, ow) = Self::out_hw(h, w, stride);
                Some(((oh * ow) as u64, (k * k * cin) as u64, cout as u64))
            }
            Layer::Dense { cin, cout } => Some((1, cin as u64, cout as u64)),
            Layer::MatMul { m, k, n } => Some((m as u64, k as u64, n as u64)),
            _ => None,
        }
    }
}

/// A named DNN workload.
#[derive(Debug, Clone)]
pub struct DnnWorkload {
    pub name: &'static str,
    pub layers: Vec<Layer>,
}

impl DnnWorkload {
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(|l| l.weights()).sum()
    }

    pub fn total_vector_ops(&self) -> u64 {
        self.layers.iter().map(|l| l.vector_ops()).sum()
    }
}

/// Non-DNN statistical ML algorithms (paper Table 1 benchmarks).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NonDnnAlgo {
    Svm,
    LinearRegression,
    LogisticRegression,
    Recsys,
    Backprop,
}

impl NonDnnAlgo {
    pub const ALL: [NonDnnAlgo; 5] = [
        NonDnnAlgo::Svm,
        NonDnnAlgo::LinearRegression,
        NonDnnAlgo::LogisticRegression,
        NonDnnAlgo::Recsys,
        NonDnnAlgo::Backprop,
    ];

    pub fn from_name(s: &str) -> Option<NonDnnAlgo> {
        Some(match s {
            "svm" => NonDnnAlgo::Svm,
            "linear_regression" => NonDnnAlgo::LinearRegression,
            "logistic_regression" => NonDnnAlgo::LogisticRegression,
            "recsys" => NonDnnAlgo::Recsys,
            "backprop" => NonDnnAlgo::Backprop,
            _ => return None,
        })
    }

    /// Registry name (inverse of `from_name`; matches the `benchmark`
    /// categorical values of the Tabla/Axiline param spaces).
    pub fn name(self) -> &'static str {
        match self {
            NonDnnAlgo::Svm => "svm",
            NonDnnAlgo::LinearRegression => "linear_regression",
            NonDnnAlgo::LogisticRegression => "logistic_regression",
            NonDnnAlgo::Recsys => "recsys",
            NonDnnAlgo::Backprop => "backprop",
        }
    }
}

/// A training workload for TABLA / Axiline.
#[derive(Debug, Clone, Copy)]
pub struct NonDnnWorkload {
    pub algo: NonDnnAlgo,
    /// Model dimension (features; recsys: latent factors x users proxy).
    pub features: usize,
    /// Training vectors per epoch.
    pub samples: usize,
    /// Training epochs.
    pub epochs: usize,
}

impl NonDnnWorkload {
    /// Default sizing per algorithm (paper's benchmark suite scale).
    pub fn standard(algo: NonDnnAlgo, features: usize) -> NonDnnWorkload {
        let (samples, epochs) = match algo {
            NonDnnAlgo::Svm => (4096, 10),
            NonDnnAlgo::LinearRegression => (4096, 10),
            NonDnnAlgo::LogisticRegression => (4096, 12),
            NonDnnAlgo::Recsys => (8192, 8),
            NonDnnAlgo::Backprop => (2048, 15),
        };
        NonDnnWorkload { algo, features, samples, epochs }
    }

    /// MAC operations per training sample.
    pub fn macs_per_sample(&self) -> u64 {
        let d = self.features as u64;
        match self.algo {
            // dot + gradient update
            NonDnnAlgo::Svm | NonDnnAlgo::LinearRegression => 2 * d,
            // dot + sigmoid (LUT) + update
            NonDnnAlgo::LogisticRegression => 2 * d + 8,
            // two factor vectors: predict + two updates
            NonDnnAlgo::Recsys => 3 * d,
            // 2-layer MLP fwd + bwd: ~4 * d * hidden(16)
            NonDnnAlgo::Backprop => 4 * d * 16,
        }
    }

    pub fn total_macs(&self) -> u64 {
        self.macs_per_sample() * (self.samples * self.epochs) as u64
    }
}

/// A registry entry: what the oracle simulators should run. DNN specs
/// bind to the systolic simulators (GeneSys, VTA); non-DNN specs bind
/// to the training-accelerator simulators (TABLA, Axiline).
#[derive(Debug, Clone)]
pub enum WorkloadSpec {
    Dnn(DnnWorkload),
    NonDnn(NonDnnWorkload),
}

impl WorkloadSpec {
    pub fn name(&self) -> &'static str {
        match self {
            WorkloadSpec::Dnn(net) => net.name,
            WorkloadSpec::NonDnn(wl) => wl.algo.name(),
        }
    }

    pub fn is_dnn(&self) -> bool {
        matches!(self, WorkloadSpec::Dnn(_))
    }
}

/// Every name the [`lookup`] registry resolves (the `--workload` axis).
pub const NAMES: [&str; 9] = [
    "mobilenet",
    "resnet50",
    "transformer",
    "gcn",
    "svm",
    "linear_regression",
    "logistic_regression",
    "recsys",
    "backprop",
];

/// Resolve a workload name with non-DNN specs at their per-platform
/// default sizing (`features` — e.g. 55 for Axiline, 64 for Tabla).
/// Unknown names error with the full registry listing; nothing in the
/// stack silently falls back to a default workload.
pub fn lookup_with_features(name: &str, features: usize) -> Result<WorkloadSpec> {
    Ok(match name {
        "mobilenet" | "mobilenet_v1" => WorkloadSpec::Dnn(mobilenet_v1()),
        "resnet50" => WorkloadSpec::Dnn(resnet50()),
        "transformer" => WorkloadSpec::Dnn(transformer_encoder()),
        "gcn" => WorkloadSpec::Dnn(gcn_two_layer()),
        other => match NonDnnAlgo::from_name(other) {
            Some(algo) => WorkloadSpec::NonDnn(NonDnnWorkload::standard(algo, features)),
            None => bail!(
                "unknown workload {:?} (available: {})",
                other,
                NAMES.join(", ")
            ),
        },
    })
}

/// [`lookup_with_features`] at the paper's Axiline sizing (55 model
/// features) — the default for callers without a platform context.
pub fn lookup(name: &str) -> Result<WorkloadSpec> {
    lookup_with_features(name, 55)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet50_scale_is_right() {
        let net = resnet50();
        let gmacs = net.total_macs() as f64 / 1e9;
        // canonical ResNet-50: ~4.1 GMACs, ~25.5M params
        assert!((3.0..5.5).contains(&gmacs), "GMACs={gmacs}");
        let mparams = net.total_weights() as f64 / 1e6;
        assert!((20.0..30.0).contains(&mparams), "Mparams={mparams}");
    }

    #[test]
    fn mobilenet_scale_is_right() {
        let net = mobilenet_v1();
        let gmacs = net.total_macs() as f64 / 1e9;
        // canonical MobileNet-v1: ~0.57 GMACs, ~4.2M params
        assert!((0.4..0.8).contains(&gmacs), "GMACs={gmacs}");
        let mparams = net.total_weights() as f64 / 1e6;
        assert!((3.0..6.0).contains(&mparams), "Mparams={mparams}");
    }

    #[test]
    fn mobilenet_is_depthwise_heavy() {
        let net = mobilenet_v1();
        let dw = net
            .layers
            .iter()
            .filter(|l| matches!(l, Layer::DwConv { .. }))
            .count();
        assert!(dw >= 13, "dw layers = {dw}");
    }

    #[test]
    fn gemm_view_consistent_with_macs() {
        let l = Layer::Conv { h: 56, w: 56, cin: 64, cout: 64, k: 3, stride: 1 };
        let (m, k, n) = l.as_gemm().unwrap();
        assert_eq!(m * k * n, l.macs());
    }

    #[test]
    fn matmul_accounting_is_consistent() {
        let l = Layer::MatMul { m: 128, k: 768, n: 3072 };
        assert_eq!(l.macs(), 128 * 768 * 3072);
        let (m, k, n) = l.as_gemm().unwrap();
        assert_eq!(m * k * n, l.macs());
        assert_eq!(l.weights(), 768 * 3072);
        assert_eq!(l.input_elems(), 128 * 768);
        assert_eq!(l.output_elems(), 128 * 3072);
        // fused epilogue on outputs, matching the Conv convention
        assert_eq!(l.vector_ops(), l.output_elems());
    }

    #[test]
    fn transformer_op_counts_are_pinned() {
        let net = transformer_encoder();
        // 12-layer / seq-128 / d768 / ffn3072 encoder + 1000-way head:
        // exact totals pinned so any table edit is a conscious choice
        assert_eq!(net.total_macs(), 11_174_393_856);
        assert_eq!(net.total_vector_ops(), 23_593_960);
        assert_eq!(net.total_weights(), 85_899_264);
        // attention/matmul-heavy: MatMul layers carry ~all the MACs
        let mm_macs: u64 = net
            .layers
            .iter()
            .filter(|l| matches!(l, Layer::MatMul { .. }))
            .map(|l| l.macs())
            .sum();
        assert!(mm_macs as f64 / net.total_macs() as f64 > 0.999);
    }

    #[test]
    fn gcn_op_counts_are_pinned() {
        let net = gcn_two_layer();
        assert_eq!(net.total_macs(), 62_641_456);
        assert_eq!(net.total_vector_ops(), 186_852);
        assert_eq!(net.total_weights(), 23_132);
        // transform dominates aggregation at Cora scale
        let transform = net.layers[0].macs();
        assert!(transform as f64 / net.total_macs() as f64 > 0.9);
    }

    #[test]
    fn registry_resolves_every_name() {
        for name in NAMES {
            let spec = lookup(name).unwrap();
            // "mobilenet" is an alias for the mobilenet_v1 layer table
            assert!(
                spec.name() == name || (name == "mobilenet" && spec.name() == "mobilenet_v1"),
                "{name} resolved to {}",
                spec.name()
            );
        }
        assert!(lookup("mobilenet").unwrap().is_dnn());
        assert!(!lookup("svm").unwrap().is_dnn());
        match lookup_with_features("backprop", 64).unwrap() {
            WorkloadSpec::NonDnn(wl) => {
                assert_eq!(wl.algo, NonDnnAlgo::Backprop);
                assert_eq!(wl.features, 64);
            }
            other => panic!("backprop resolved to {other:?}"),
        }
    }

    #[test]
    fn unknown_workload_error_lists_available() {
        let err = lookup("lenet").unwrap_err().to_string();
        assert!(err.contains("lenet"));
        for name in NAMES {
            assert!(err.contains(name), "error should list {name}: {err}");
        }
    }

    #[test]
    fn nondnn_backprop_dominates() {
        let svm = NonDnnWorkload::standard(NonDnnAlgo::Svm, 55);
        let bp = NonDnnWorkload::standard(NonDnnAlgo::Backprop, 55);
        assert!(bp.total_macs() > 10 * svm.total_macs());
    }

    #[test]
    fn conv_shapes_track_stride() {
        let l = Layer::Conv { h: 224, w: 224, cin: 3, cout: 64, k: 7, stride: 2 };
        assert_eq!(l.output_elems(), 112 * 112 * 64);
    }
}
