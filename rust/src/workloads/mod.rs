//! Workload descriptions (paper §3: cost metrics depend on network
//! topology, not input data). ResNet-50 and MobileNet-v1 layer tables
//! drive the DNN simulators (GeneSys, VTA); the non-DNN algorithm specs
//! drive TABLA and Axiline.

pub mod mobilenet;
pub mod resnet50;

pub use mobilenet::mobilenet_v1;
pub use resnet50::resnet50;

/// One DNN layer as the simulators see it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Layer {
    /// Convolution: input H x W x Cin, K x K kernel, Cout filters.
    Conv { h: usize, w: usize, cin: usize, cout: usize, k: usize, stride: usize },
    /// Depthwise convolution (per-channel K x K).
    DwConv { h: usize, w: usize, c: usize, k: usize, stride: usize },
    /// Fully connected.
    Dense { cin: usize, cout: usize },
    /// Global/strided pooling over H x W x C.
    Pool { h: usize, w: usize, c: usize, k: usize, stride: usize },
    /// Elementwise activation over N values (ReLU etc.).
    Act { n: usize },
}

impl Layer {
    /// Output spatial size of a conv-like layer (same padding).
    fn out_hw(h: usize, w: usize, stride: usize) -> (usize, usize) {
        (h.div_ceil(stride), w.div_ceil(stride))
    }

    /// Multiply-accumulate count.
    pub fn macs(&self) -> u64 {
        match *self {
            Layer::Conv { h, w, cin, cout, k, stride } => {
                let (oh, ow) = Self::out_hw(h, w, stride);
                (oh * ow) as u64 * (k * k * cin) as u64 * cout as u64
            }
            Layer::DwConv { h, w, c, k, stride } => {
                let (oh, ow) = Self::out_hw(h, w, stride);
                (oh * ow) as u64 * (k * k) as u64 * c as u64
            }
            Layer::Dense { cin, cout } => (cin * cout) as u64,
            Layer::Pool { .. } | Layer::Act { .. } => 0,
        }
    }

    /// Vector (non-MAC) op count: pooling reads + activations.
    pub fn vector_ops(&self) -> u64 {
        match *self {
            Layer::Pool { h, w, c, k, stride } => {
                let (oh, ow) = Self::out_hw(h, w, stride);
                (oh * ow * c) as u64 * (k * k) as u64
            }
            Layer::Act { n } => n as u64,
            Layer::Conv { h, w, cout, stride, .. } => {
                // fused bias+ReLU on outputs
                let (oh, ow) = Self::out_hw(h, w, stride);
                (oh * ow * cout) as u64
            }
            Layer::DwConv { h, w, c, stride, .. } => {
                let (oh, ow) = Self::out_hw(h, w, stride);
                (oh * ow * c) as u64
            }
            Layer::Dense { cout, .. } => cout as u64,
        }
    }

    /// Weight parameter count.
    pub fn weights(&self) -> u64 {
        match *self {
            Layer::Conv { cin, cout, k, .. } => (k * k * cin * cout) as u64,
            Layer::DwConv { c, k, .. } => (k * k * c) as u64,
            Layer::Dense { cin, cout } => (cin * cout) as u64,
            Layer::Pool { .. } | Layer::Act { .. } => 0,
        }
    }

    /// Input activation element count.
    pub fn input_elems(&self) -> u64 {
        match *self {
            Layer::Conv { h, w, cin, .. } => (h * w * cin) as u64,
            Layer::DwConv { h, w, c, .. } => (h * w * c) as u64,
            Layer::Dense { cin, .. } => cin as u64,
            Layer::Pool { h, w, c, .. } => (h * w * c) as u64,
            Layer::Act { n } => n as u64,
        }
    }

    /// Output activation element count.
    pub fn output_elems(&self) -> u64 {
        match *self {
            Layer::Conv { h, w, cout, stride, .. } => {
                let (oh, ow) = Self::out_hw(h, w, stride);
                (oh * ow * cout) as u64
            }
            Layer::DwConv { h, w, c, stride, .. } => {
                let (oh, ow) = Self::out_hw(h, w, stride);
                (oh * ow * c) as u64
            }
            Layer::Dense { cout, .. } => cout as u64,
            Layer::Pool { h, w, c, k: _, stride } => {
                let (oh, ow) = Self::out_hw(h, w, stride);
                (oh * ow * c) as u64
            }
            Layer::Act { n } => n as u64,
        }
    }

    /// As a GEMM (M, K, N): output-pixels x reduction x filters.
    pub fn as_gemm(&self) -> Option<(u64, u64, u64)> {
        match *self {
            Layer::Conv { h, w, cin, cout, k, stride } => {
                let (oh, ow) = Self::out_hw(h, w, stride);
                Some(((oh * ow) as u64, (k * k * cin) as u64, cout as u64))
            }
            Layer::Dense { cin, cout } => Some((1, cin as u64, cout as u64)),
            _ => None,
        }
    }
}

/// A named DNN workload.
#[derive(Debug, Clone)]
pub struct DnnWorkload {
    pub name: &'static str,
    pub layers: Vec<Layer>,
}

impl DnnWorkload {
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(|l| l.weights()).sum()
    }
}

/// Non-DNN statistical ML algorithms (paper Table 1 benchmarks).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NonDnnAlgo {
    Svm,
    LinearRegression,
    LogisticRegression,
    Recsys,
    Backprop,
}

impl NonDnnAlgo {
    pub fn from_name(s: &str) -> Option<NonDnnAlgo> {
        Some(match s {
            "svm" => NonDnnAlgo::Svm,
            "linear_regression" => NonDnnAlgo::LinearRegression,
            "logistic_regression" => NonDnnAlgo::LogisticRegression,
            "recsys" => NonDnnAlgo::Recsys,
            "backprop" => NonDnnAlgo::Backprop,
            _ => return None,
        })
    }
}

/// A training workload for TABLA / Axiline.
#[derive(Debug, Clone, Copy)]
pub struct NonDnnWorkload {
    pub algo: NonDnnAlgo,
    /// Model dimension (features; recsys: latent factors x users proxy).
    pub features: usize,
    /// Training vectors per epoch.
    pub samples: usize,
    /// Training epochs.
    pub epochs: usize,
}

impl NonDnnWorkload {
    /// Default sizing per algorithm (paper's benchmark suite scale).
    pub fn standard(algo: NonDnnAlgo, features: usize) -> NonDnnWorkload {
        let (samples, epochs) = match algo {
            NonDnnAlgo::Svm => (4096, 10),
            NonDnnAlgo::LinearRegression => (4096, 10),
            NonDnnAlgo::LogisticRegression => (4096, 12),
            NonDnnAlgo::Recsys => (8192, 8),
            NonDnnAlgo::Backprop => (2048, 15),
        };
        NonDnnWorkload { algo, features, samples, epochs }
    }

    /// MAC operations per training sample.
    pub fn macs_per_sample(&self) -> u64 {
        let d = self.features as u64;
        match self.algo {
            // dot + gradient update
            NonDnnAlgo::Svm | NonDnnAlgo::LinearRegression => 2 * d,
            // dot + sigmoid (LUT) + update
            NonDnnAlgo::LogisticRegression => 2 * d + 8,
            // two factor vectors: predict + two updates
            NonDnnAlgo::Recsys => 3 * d,
            // 2-layer MLP fwd + bwd: ~4 * d * hidden(16)
            NonDnnAlgo::Backprop => 4 * d * 16,
        }
    }

    pub fn total_macs(&self) -> u64 {
        self.macs_per_sample() * (self.samples * self.epochs) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet50_scale_is_right() {
        let net = resnet50();
        let gmacs = net.total_macs() as f64 / 1e9;
        // canonical ResNet-50: ~4.1 GMACs, ~25.5M params
        assert!((3.0..5.5).contains(&gmacs), "GMACs={gmacs}");
        let mparams = net.total_weights() as f64 / 1e6;
        assert!((20.0..30.0).contains(&mparams), "Mparams={mparams}");
    }

    #[test]
    fn mobilenet_scale_is_right() {
        let net = mobilenet_v1();
        let gmacs = net.total_macs() as f64 / 1e9;
        // canonical MobileNet-v1: ~0.57 GMACs, ~4.2M params
        assert!((0.4..0.8).contains(&gmacs), "GMACs={gmacs}");
        let mparams = net.total_weights() as f64 / 1e6;
        assert!((3.0..6.0).contains(&mparams), "Mparams={mparams}");
    }

    #[test]
    fn mobilenet_is_depthwise_heavy() {
        let net = mobilenet_v1();
        let dw = net
            .layers
            .iter()
            .filter(|l| matches!(l, Layer::DwConv { .. }))
            .count();
        assert!(dw >= 13, "dw layers = {dw}");
    }

    #[test]
    fn gemm_view_consistent_with_macs() {
        let l = Layer::Conv { h: 56, w: 56, cin: 64, cout: 64, k: 3, stride: 1 };
        let (m, k, n) = l.as_gemm().unwrap();
        assert_eq!(m * k * n, l.macs());
    }

    #[test]
    fn nondnn_backprop_dominates() {
        let svm = NonDnnWorkload::standard(NonDnnAlgo::Svm, 55);
        let bp = NonDnnWorkload::standard(NonDnnAlgo::Backprop, 55);
        assert!(bp.total_macs() > 10 * svm.total_macs());
    }

    #[test]
    fn conv_shapes_track_stride() {
        let l = Layer::Conv { h: 224, w: 224, cin: 3, cout: 64, k: 7, stride: 2 };
        assert_eq!(l.output_elems(), 112 * 112 * 64);
    }
}
