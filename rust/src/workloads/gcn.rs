//! Two-layer GCN inference workload (Kipf-&-Welling shape over a
//! Cora-scale citation graph). This is the *workload-side* mirror of
//! the GCN surrogate in `models/gcn.rs`: the same
//! transform-then-aggregate structure that `GcnModel` runs over LHG
//! module graphs, expressed as a layer table the systolic simulators
//! can cost.
//!
//! Each GCN layer is two matmuls: the dense feature transform
//! `X · W` (N x Fin by Fin x Fout) and the sparse neighborhood
//! aggregation `Â · (XW)`, costed at one MAC per (edge, feature) —
//! i.e. a `MatMul` whose reduction depth is the mean degree — plus an
//! activation epilogue (ReLU after layer 1, softmax after layer 2).

use super::{DnnWorkload, Layer};

/// Graph nodes (Cora scale).
pub const NODES: usize = 2708;
/// Mean in-degree used to cost the sparse aggregation matmul.
pub const MEAN_DEGREE: usize = 4;
/// Input feature dimension.
pub const F_IN: usize = 1433;
/// Hidden dimension (matches the 2-layer GCN in `models/gcn.rs`).
pub const F_HIDDEN: usize = 16;
/// Output classes.
pub const F_OUT: usize = 7;

fn gcn_layer(layers: &mut Vec<Layer>, f_in: usize, f_out: usize) {
    // dense feature transform X · W
    layers.push(Layer::MatMul { m: NODES, k: f_in, n: f_out });
    // normalized-adjacency aggregation Â · (XW): one MAC per
    // (edge, output feature)
    layers.push(Layer::MatMul { m: NODES, k: MEAN_DEGREE, n: f_out });
    // ReLU / softmax epilogue
    layers.push(Layer::Act { n: NODES * f_out });
}

/// The `gcn` registry workload.
pub fn gcn_two_layer() -> DnnWorkload {
    let mut layers = Vec::new();
    gcn_layer(&mut layers, F_IN, F_HIDDEN);
    gcn_layer(&mut layers, F_HIDDEN, F_OUT);
    DnnWorkload { name: "gcn", layers }
}
