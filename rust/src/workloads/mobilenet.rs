//! MobileNet-v1 layer table (ImageNet 224x224, width 1.0), the VTA
//! workload in the paper's system-level experiments (§7.1). Its
//! depthwise-separable structure is the interesting case for VTA: the
//! GEMM core handles pointwise convs well but depthwise convs fall to
//! the tensor ALU.

use super::{DnnWorkload, Layer};

fn dw_sep(layers: &mut Vec<Layer>, h: usize, w: usize, cin: usize, cout: usize, stride: usize) {
    layers.push(Layer::DwConv { h, w, c: cin, k: 3, stride });
    let (oh, ow) = (h.div_ceil(stride), w.div_ceil(stride));
    layers.push(Layer::Conv { h: oh, w: ow, cin, cout, k: 1, stride: 1 });
    layers.push(Layer::Act { n: oh * ow * cout });
}

pub fn mobilenet_v1() -> DnnWorkload {
    let mut layers = Vec::new();
    layers.push(Layer::Conv { h: 224, w: 224, cin: 3, cout: 32, k: 3, stride: 2 });
    dw_sep(&mut layers, 112, 112, 32, 64, 1);
    dw_sep(&mut layers, 112, 112, 64, 128, 2);
    dw_sep(&mut layers, 56, 56, 128, 128, 1);
    dw_sep(&mut layers, 56, 56, 128, 256, 2);
    dw_sep(&mut layers, 28, 28, 256, 256, 1);
    dw_sep(&mut layers, 28, 28, 256, 512, 2);
    for _ in 0..5 {
        dw_sep(&mut layers, 14, 14, 512, 512, 1);
    }
    dw_sep(&mut layers, 14, 14, 512, 1024, 2);
    dw_sep(&mut layers, 7, 7, 1024, 1024, 1);
    layers.push(Layer::Pool { h: 7, w: 7, c: 1024, k: 7, stride: 7 });
    layers.push(Layer::Dense { cin: 1024, cout: 1000 });
    DnnWorkload { name: "mobilenet_v1", layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thirteen_separable_blocks() {
        let net = mobilenet_v1();
        let dw = net.layers.iter().filter(|l| matches!(l, Layer::DwConv { .. })).count();
        assert_eq!(dw, 13);
    }

    #[test]
    fn pointwise_convs_dominate_macs() {
        let net = mobilenet_v1();
        let dw_macs: u64 = net
            .layers
            .iter()
            .filter(|l| matches!(l, Layer::DwConv { .. }))
            .map(|l| l.macs())
            .sum();
        let total = net.total_macs();
        assert!((dw_macs as f64) < 0.1 * total as f64);
    }
}
