//! ResNet-50 layer table (ImageNet 224x224), the GeneSys workload in the
//! paper's system-level experiments (§7.1).

use super::{DnnWorkload, Layer};

/// Bottleneck block: 1x1 reduce, 3x3, 1x1 expand (+ optional downsample).
fn bottleneck(
    layers: &mut Vec<Layer>,
    h: usize,
    w: usize,
    cin: usize,
    cmid: usize,
    cout: usize,
    stride: usize,
    downsample: bool,
) {
    layers.push(Layer::Conv { h, w, cin, cout: cmid, k: 1, stride: 1 });
    layers.push(Layer::Conv { h, w, cin: cmid, cout: cmid, k: 3, stride });
    let (oh, ow) = (h.div_ceil(stride), w.div_ceil(stride));
    layers.push(Layer::Conv { h: oh, w: ow, cin: cmid, cout, k: 1, stride: 1 });
    if downsample {
        layers.push(Layer::Conv { h, w, cin, cout, k: 1, stride });
    }
    layers.push(Layer::Act { n: oh * ow * cout });
}

/// Full ResNet-50: conv1 + 4 stages (3,4,6,3 bottlenecks) + fc.
pub fn resnet50() -> DnnWorkload {
    let mut layers = Vec::new();
    layers.push(Layer::Conv { h: 224, w: 224, cin: 3, cout: 64, k: 7, stride: 2 });
    layers.push(Layer::Pool { h: 112, w: 112, c: 64, k: 3, stride: 2 });

    // stage 1: 56x56, 64 -> 256
    bottleneck(&mut layers, 56, 56, 64, 64, 256, 1, true);
    for _ in 0..2 {
        bottleneck(&mut layers, 56, 56, 256, 64, 256, 1, false);
    }
    // stage 2: 56 -> 28, 256 -> 512
    bottleneck(&mut layers, 56, 56, 256, 128, 512, 2, true);
    for _ in 0..3 {
        bottleneck(&mut layers, 28, 28, 512, 128, 512, 1, false);
    }
    // stage 3: 28 -> 14, 512 -> 1024
    bottleneck(&mut layers, 28, 28, 512, 256, 1024, 2, true);
    for _ in 0..5 {
        bottleneck(&mut layers, 14, 14, 1024, 256, 1024, 1, false);
    }
    // stage 4: 14 -> 7, 1024 -> 2048
    bottleneck(&mut layers, 14, 14, 1024, 512, 2048, 2, true);
    for _ in 0..2 {
        bottleneck(&mut layers, 7, 7, 2048, 512, 2048, 1, false);
    }

    layers.push(Layer::Pool { h: 7, w: 7, c: 2048, k: 7, stride: 7 });
    layers.push(Layer::Dense { cin: 2048, cout: 1000 });

    DnnWorkload { name: "resnet50", layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn has_53_convs_and_one_fc() {
        let net = resnet50();
        let convs = net.layers.iter().filter(|l| matches!(l, Layer::Conv { .. })).count();
        let fcs = net.layers.iter().filter(|l| matches!(l, Layer::Dense { .. })).count();
        assert_eq!(convs, 53);
        assert_eq!(fcs, 1);
    }

    #[test]
    fn first_stage_is_the_published_shape() {
        let net = resnet50();
        assert_eq!(
            net.layers[0],
            Layer::Conv { h: 224, w: 224, cin: 3, cout: 64, k: 7, stride: 2 }
        );
    }
}
