//! Transformer encoder workload (BERT-base-shaped): the
//! attention/matmul-heavy layer mix the paper's DNN scope implies but
//! the seed repo never exercised. Every projection, attention product,
//! and FFN stage is a [`Layer::MatMul`]; softmax/GELU/layer-norm
//! epilogues are [`Layer::Act`] vector work; the classifier head is a
//! plain [`Layer::Dense`].
//!
//! Shape: 12 layers, sequence 128, d_model 768, 12 heads (head dim
//! 64), FFN 3072 — ~11.2 GMACs and ~86M parameters (BERT-base sans
//! embedding tables), pinned exactly by the tests in `workloads/mod.rs`.

use super::{DnnWorkload, Layer};

/// Sequence length the encoder is profiled at.
pub const SEQ: usize = 128;
/// Model (hidden) dimension.
pub const D_MODEL: usize = 768;
/// Attention heads; head dimension is `D_MODEL / HEADS`.
pub const HEADS: usize = 12;
/// FFN inner dimension.
pub const D_FFN: usize = 3072;
/// Encoder layer count.
pub const LAYERS: usize = 12;

/// One encoder layer: QKV projections, per-head QKᵀ and A·V products
/// (batched over heads in the M dimension), output projection, and the
/// two FFN matmuls, with Act layers for softmax / residual+LN / GELU.
fn encoder_layer(layers: &mut Vec<Layer>) {
    let dh = D_MODEL / HEADS;
    // Q, K, V projections: (SEQ x D_MODEL) · (D_MODEL x D_MODEL)
    for _ in 0..3 {
        layers.push(Layer::MatMul { m: SEQ, k: D_MODEL, n: D_MODEL });
    }
    // attention scores QKᵀ: per head (SEQ x dh) · (dh x SEQ), heads
    // folded into M
    layers.push(Layer::MatMul { m: SEQ * HEADS, k: dh, n: SEQ });
    // softmax over every score
    layers.push(Layer::Act { n: HEADS * SEQ * SEQ });
    // A·V: per head (SEQ x SEQ) · (SEQ x dh)
    layers.push(Layer::MatMul { m: SEQ * HEADS, k: SEQ, n: dh });
    // output projection + residual/layer-norm epilogue
    layers.push(Layer::MatMul { m: SEQ, k: D_MODEL, n: D_MODEL });
    layers.push(Layer::Act { n: SEQ * D_MODEL });
    // FFN up / GELU / FFN down + residual/layer-norm epilogue
    layers.push(Layer::MatMul { m: SEQ, k: D_MODEL, n: D_FFN });
    layers.push(Layer::Act { n: SEQ * D_FFN });
    layers.push(Layer::MatMul { m: SEQ, k: D_FFN, n: D_MODEL });
    layers.push(Layer::Act { n: SEQ * D_MODEL });
}

/// The `transformer` registry workload.
pub fn transformer_encoder() -> DnnWorkload {
    let mut layers = Vec::new();
    for _ in 0..LAYERS {
        encoder_layer(&mut layers);
    }
    // classifier head over the pooled token
    layers.push(Layer::Dense { cin: D_MODEL, cout: 1000 });
    DnnWorkload { name: "transformer", layers }
}
