//! Named benchmark suites with saved baselines and a machine-checked
//! perf gate (criterion is unavailable offline).
//!
//! The ad-hoc `cargo bench` harness prints medians but nothing ever
//! *checks* them, so a perf claim in a PR is asserted, not enforced.
//! This module turns the hot-path rows into named suites that emit
//! `BENCH_<suite>.json` trajectory points, and gives the CLI a
//! `fso bench compare` subcommand that diffs a fresh run (or a saved
//! candidate file) against a prior trajectory point and fails past a
//! noise threshold — which is what the CI `perf-gate` job runs.
//!
//! Two kinds of measurements live in a [`SuiteReport`]:
//!
//! * **rows** — absolute medians (ms) with MAD error bars. Only
//!   comparable on the same machine; the CI gate runs the suite twice
//!   (baseline + candidate) in one job so the comparison is honest.
//! * **derived** — dimensionless ratios (speedups, occupancies).
//!   Machine-portable by construction; by convention **higher is
//!   better**, so a candidate regresses when it drops below
//!   `baseline * (1 - threshold)`. The committed seed baselines under
//!   `rust/benches/baselines/` are compared `--derived-only`.
//!
//! Adding a gated suite: write a `fn my_suite(quick: bool) ->
//! Result<SuiteReport>` next to [`flat_tree`], register its name in
//! [`SUITES`] and [`run_suite`], give it self-invariants in
//! [`check_invariants`] if it makes a claim every run must uphold,
//! commit a generated `BENCH_<suite>.json` as its seed baseline, and
//! add it to the CI `perf-gate` matrix.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Registered suite names (`fso bench list`).
pub const SUITES: &[&str] = &["flat_tree", "store_v2", "dse_strategies", "fleet"];

/// One timed row: the median of `reps` timed runs and the median
/// absolute deviation around it.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRow {
    pub name: String,
    pub median_ms: f64,
    pub mad_ms: f64,
    pub reps: usize,
}

/// One suite run — the unit `BENCH_<suite>.json` persists and
/// [`compare`] diffs.
#[derive(Debug, Clone, PartialEq)]
pub struct SuiteReport {
    pub suite: String,
    pub quick: bool,
    pub rows: Vec<BenchRow>,
    /// Machine-portable ratios; higher is better by convention.
    pub derived: BTreeMap<String, f64>,
}

impl SuiteReport {
    pub fn row(&self, name: &str) -> Option<&BenchRow> {
        self.rows.iter().find(|r| r.name == name)
    }

    /// Human-readable table (mirrors the `cargo bench` harness format).
    pub fn render(&self) -> String {
        let mut s = format!(
            "suite {} ({} mode)\n",
            self.suite,
            if self.quick { "quick" } else { "full" }
        );
        for r in &self.rows {
            s.push_str(&format!(
                "{:<46} {:>10.3} ms  (+-{:.3})\n",
                r.name, r.median_ms, r.mad_ms
            ));
        }
        for (k, v) in &self.derived {
            s.push_str(&format!("derived/{k:<38} {v:>10.3}\n"));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", 1usize.into()),
            ("suite", Json::Str(self.suite.clone())),
            ("quick", Json::Bool(self.quick)),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("name", Json::Str(r.name.clone())),
                                ("median_ms", r.median_ms.into()),
                                ("mad_ms", r.mad_ms.into()),
                                ("reps", r.reps.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "derived",
                Json::Obj(
                    self.derived
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Num(*v)))
                        .collect(),
                ),
            ),
        ])
    }

    /// Strict inverse of `to_json`: `None` on any structural defect,
    /// so a corrupt baseline file is an explicit error, not a silent
    /// empty comparison.
    pub fn from_json(j: &Json) -> Option<SuiteReport> {
        let suite = j.get("suite").as_str()?.to_string();
        let quick = j.get("quick").as_bool().unwrap_or(false);
        let mut rows = Vec::new();
        for r in j.get("rows").as_arr()? {
            rows.push(BenchRow {
                name: r.get("name").as_str()?.to_string(),
                median_ms: r.get("median_ms").as_f64()?,
                mad_ms: r.get("mad_ms").as_f64().unwrap_or(0.0),
                reps: r.get("reps").as_usize().unwrap_or(0),
            });
        }
        let mut derived = BTreeMap::new();
        for (k, v) in j.get("derived").as_obj()? {
            derived.insert(k.clone(), v.as_f64()?);
        }
        Some(SuiteReport { suite, quick, rows, derived })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut text = self.to_json().to_string();
        text.push('\n');
        std::fs::write(path, text)
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<SuiteReport> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(text.trim())
            .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        SuiteReport::from_json(&j)
            .with_context(|| format!("{} is not a bench report", path.display()))
    }
}

/// Default trajectory-point filename for a suite.
pub fn default_out(suite: &str) -> String {
    format!("BENCH_{suite}.json")
}

/// Warmup + repetition timer (median/MAD), shared with the `cargo
/// bench` harness conventions: quick = (1 warmup, 5 reps), full =
/// (3, 15).
struct Timer {
    warmup: usize,
    reps: usize,
}

impl Timer {
    fn new(quick: bool) -> Timer {
        let (warmup, reps) = if quick { (1, 5) } else { (3, 15) };
        Timer { warmup, reps }
    }

    fn measure<R, F: FnMut() -> R>(&self, mut f: F) -> (f64, f64) {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times: Vec<f64> = (0..self.reps)
            .map(|_| {
                let t0 = Instant::now();
                std::hint::black_box(f());
                t0.elapsed().as_secs_f64() * 1e3
            })
            .collect();
        times.sort_by(|a, b| a.total_cmp(b));
        let median = times[times.len() / 2];
        let mut dev: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
        dev.sort_by(|a, b| a.total_cmp(b));
        (median, dev[dev.len() / 2])
    }
}

/// Run a named suite.
pub fn run_suite(suite: &str, quick: bool) -> Result<SuiteReport> {
    match suite {
        "flat_tree" => flat_tree(quick),
        "store_v2" => store_v2(quick),
        "dse_strategies" => dse_strategies(quick),
        "fleet" => fleet(quick),
        other => bail!("unknown bench suite {other:?} (available: {})", SUITES.join(", ")),
    }
}

/// Per-suite self-invariants, checked on every fresh run independent
/// of any baseline. For `flat_tree`: the mega-batch flat path must
/// actually beat the recursive reference — the measured speedup this
/// PR claims is machine-checked here and in the CI perf-gate job.
pub fn check_invariants(report: &SuiteReport) -> Result<()> {
    if report.suite == "flat_tree" {
        let speedup = report
            .derived
            .get("speedup_mega")
            .copied()
            .context("flat_tree report is missing derived speedup_mega")?;
        anyhow::ensure!(
            speedup >= 1.0,
            "flat mega-batch inference is slower than the recursive reference \
             ({speedup:.2}x < 1.0x)"
        );
    }
    if report.suite == "store_v2" {
        // the storage-engine-v2 claims, machine-checked every run:
        // streaming scan beats eager decode, sidecar point lookups beat
        // the scan fallback, and the v2 framing is no larger than v1
        for key in ["shard_load_speedup", "point_lookup_speedup", "codec_bytes_ratio"] {
            let v = report
                .derived
                .get(key)
                .copied()
                .with_context(|| format!("store_v2 report is missing derived {key}"))?;
            anyhow::ensure!(v >= 1.0, "store_v2 {key} fell below 1.0 ({v:.3})");
        }
    }
    if report.suite == "dse_strategies" {
        // the pipelined cadence overlaps proposal generation with
        // featurize+score workers; it must never lose to strict
        // alternation at the same seed
        let v = report
            .derived
            .get("pipelined_vs_strict")
            .copied()
            .context("dse_strategies report is missing derived pipelined_vs_strict")?;
        anyhow::ensure!(
            v >= 1.0,
            "pipelined DSE cadence is slower than strict alternation ({v:.3}x < 1.0x)"
        );
    }
    if report.suite == "fleet" {
        // parked waiters idle while one flight leader runs; stealing
        // waiters drain the rest of the batch instead — the scale-out
        // claim of the work-stealing single-flight (ISSUE 10)
        let v = report
            .derived
            .get("steal_vs_park")
            .copied()
            .context("fleet report is missing derived steal_vs_park")?;
        anyhow::ensure!(
            v >= 1.0,
            "work-stealing single-flight is slower than parked waiters ({v:.3}x < 1.0x)"
        );
    }
    Ok(())
}

/// The `flat_tree` suite: cold (recursive per-row reference walkers)
/// vs flat SoA `predict_batch` over the two-stage surrogate at small /
/// medium / mega batch sizes, plus the `EvalRouter` occupancy rerun.
/// The differential bit-identity check rides along on every batch
/// size, so the bench doubles as an end-to-end equivalence harness.
fn flat_tree(quick: bool) -> Result<SuiteReport> {
    use crate::backend::Enablement;
    use crate::coordinator::dse_driver::SurrogateBundle;
    use crate::coordinator::{datagen, DatagenConfig, EvalRouter, EvalService};
    use crate::data::Metric;
    use crate::generators::Platform;
    use std::sync::Arc;

    let t = Timer::new(quick);
    let g = datagen::generate(&DatagenConfig {
        n_arch: 6,
        n_backend_train: 8,
        n_backend_test: 2,
        ..DatagenConfig::small(Platform::Axiline, Enablement::Gf12)
    })?;
    let bundle = SurrogateBundle::fit(&g.dataset, &g.backend_split, 7)?;
    let feats: Vec<Vec<f64>> =
        g.dataset.rows.iter().map(|r| r.features_vec()).collect();

    let mut rows_out: Vec<BenchRow> = Vec::new();
    let mut derived = BTreeMap::new();

    {
        // the pre-flat scoring path: per-row recursive classifier prob
        // + per-row, per-metric regressor walk + exp — what every
        // mega-batch used to degrade to
        let reference = |rows: &[Vec<f64>]| {
            let mut out = Vec::with_capacity(rows.len());
            for x in rows {
                let p = bundle.classifier.prob(x);
                let mut preds = BTreeMap::new();
                for m in Metric::ALL {
                    preds.insert(m, bundle.regressors[&m].predict_one(x).exp());
                }
                out.push((p >= 0.5, preds));
            }
            out
        };

        for (tag, size) in [("small", 32usize), ("medium", 512), ("mega", 4096)] {
            let batch: Vec<Vec<f64>> =
                (0..size).map(|i| feats[i % feats.len()].clone()).collect();

            // differential check first: flat == recursive, bit for bit
            let flat_out = bundle.predict_batch(&batch, 1);
            let ref_out = reference(&batch);
            for (i, (f, r)) in flat_out.iter().zip(&ref_out).enumerate() {
                anyhow::ensure!(
                    f.0 == r.0,
                    "row {i}: flat ROI gate diverged from the recursive reference"
                );
                for m in Metric::ALL {
                    anyhow::ensure!(
                        f.1[&m].to_bits() == r.1[&m].to_bits(),
                        "row {i} metric {m}: flat prediction is not bit-identical \
                         to the recursive reference"
                    );
                }
            }

            let (med, mad) = t.measure(|| reference(&batch));
            rows_out.push(BenchRow {
                name: format!("surrogate/recursive/batch_{size}"),
                median_ms: med,
                mad_ms: mad,
                reps: t.reps,
            });
            let (fmed, fmad) = t.measure(|| bundle.predict_batch(&batch, 1));
            rows_out.push(BenchRow {
                name: format!("surrogate/flat/batch_{size}"),
                median_ms: fmed,
                mad_ms: fmad,
                reps: t.reps,
            });
            derived.insert(format!("speedup_{tag}"), med / fmed.max(1e-9));
        }
    }

    // router-occupancy rerun: concurrent single-row clients coalescing
    // into mega-batches that now land on the flat path
    let service =
        Arc::new(EvalService::new(Enablement::Gf12, 2023).with_surrogate(bundle));
    let clients = 8usize;
    let per_client = 40usize;
    let router = EvalRouter::start(Arc::clone(&service));
    let (rmed, rmad) = t.measure(|| {
        std::thread::scope(|scope| {
            for c in 0..clients {
                let client = router.client();
                let feats = &feats;
                scope.spawn(move || {
                    for k in 0..per_client {
                        let row = feats[(c * per_client + k) % feats.len()].clone();
                        client.predict(vec![row]).expect("router predict");
                    }
                });
            }
        })
    });
    drop(router);
    rows_out.push(BenchRow {
        name: format!("router/{clients}clients_x{per_client}rows"),
        median_ms: rmed,
        mad_ms: rmad,
        reps: t.reps,
    });
    derived.insert("router_occupancy".to_string(), service.stats().router_occupancy());

    Ok(SuiteReport { suite: "flat_tree".to_string(), quick, rows: rows_out, derived })
}

/// The `store_v2` suite (ISSUE 7): storage-engine claims over a
/// populated oracle-cache directory — streaming shard loads vs the
/// eager decode-every-payload loader they replaced, `.idx` sidecar
/// point lookups vs the scan fallback (the sidecars are deleted inside
/// the measured closure), and the v1-JSONL vs v2-binary footprint of
/// the same records. Every path is differentially checked for
/// bit-identical results before timing starts.
fn store_v2(quick: bool) -> Result<SuiteReport> {
    use crate::backend::{BackendConfig, Enablement};
    use crate::coordinator::cache_store::SCHEMA_VERSION;
    use crate::coordinator::{CacheStore, Codec, EvalService};
    use crate::generators::{ArchConfig, Platform};
    use crate::util::rng::hash_bytes;
    use std::fs;

    let t = Timer::new(quick);
    let n_records: usize = if quick { 512 } else { 4096 };

    // one real ground-truth evaluation, replicated under distinct
    // content-hash keys (the store never inspects key structure)
    let arch = ArchConfig::new(
        Platform::Axiline,
        Platform::Axiline.param_space().iter().map(|s| s.kind.from_unit(0.5)).collect(),
    );
    let svc = EvalService::new(Enablement::Gf12, 7);
    let ev = svc.evaluate(&arch, BackendConfig::new(0.8, 0.5), None)?;
    let keys: Vec<u64> =
        (0..n_records as u64).map(|i| hash_bytes(&i.to_le_bytes())).collect();

    let base = std::env::temp_dir()
        .join(format!("fso-bench-store-v2-{}", std::process::id()));
    let _ = fs::remove_dir_all(&base);

    let mut rows_out: Vec<BenchRow> = Vec::new();
    let mut derived = BTreeMap::new();

    // write+flush per codec; the surviving dirs feed every later row
    let mut codec_bytes = BTreeMap::new();
    for codec in [Codec::V1Jsonl, Codec::V2Binary] {
        let dir = base.join(codec.name());
        let (med, mad) = t.measure(|| {
            let _ = fs::remove_dir_all(&dir);
            let store = CacheStore::open(&dir).unwrap().with_codec(codec);
            for &k in &keys {
                store.put_eval(k, ev);
            }
            store.flush().unwrap()
        });
        rows_out.push(BenchRow {
            name: format!("store/write_flush/{}", codec.name()),
            median_ms: med,
            mad_ms: mad,
            reps: t.reps,
        });
        let ext = format!(".{}", codec.file_ext());
        let mut total = 0u64;
        for entry in fs::read_dir(&dir)? {
            let entry = entry?;
            if entry.file_name().to_string_lossy().ends_with(&ext) {
                total += entry.metadata()?.len();
            }
        }
        anyhow::ensure!(total > 0, "no {} shard bytes written", codec.name());
        codec_bytes.insert(codec.name(), total);
    }
    derived.insert(
        "codec_bytes_ratio".to_string(),
        codec_bytes["v1"] as f64 / codec_bytes["v2"] as f64,
    );

    let v2_dir = base.join(Codec::V2Binary.name());
    let shard_paths: Vec<std::path::PathBuf> = {
        let ext = format!(".{}", Codec::V2Binary.file_ext());
        let mut ps: Vec<_> = fs::read_dir(&v2_dir)?
            .map(|e| e.unwrap().path())
            .filter(|p| p.file_name().unwrap().to_string_lossy().ends_with(&ext))
            .collect();
        ps.sort();
        ps
    };

    // differential check before timing: the streaming store serves the
    // flushed records bit-identically through both lookup paths
    {
        let store = CacheStore::open(&v2_dir)?;
        let via_sidecar = store.get_eval(keys[0]).context("sidecar lookup lost a record")?;
        anyhow::ensure!(store.shard_loads() == 0, "sidecar lookup scanned a shard");
        for p in fs::read_dir(&v2_dir)? {
            let p = p?.path();
            if p.to_string_lossy().ends_with(".idx") {
                fs::remove_file(p)?;
            }
        }
        let store = CacheStore::open(&v2_dir)?;
        let via_scan = store.get_eval(keys[0]).context("scan fallback lost a record")?;
        for got in [via_sidecar, via_scan] {
            anyhow::ensure!(
                got.flow.backend == ev.flow.backend && got.system == ev.system,
                "store round-trip diverged from the generated evaluation"
            );
        }
    }

    // shard load: the eager pre-v2 loader (decode every payload into a
    // value tree) vs the streaming envelope scan the store runs now
    let (emed, emad) = t.measure(|| {
        let mut decoded = 0usize;
        for p in &shard_paths {
            let bytes = fs::read(p).unwrap();
            Codec::V2Binary.imp().scan(&bytes, SCHEMA_VERSION, &mut |f| {
                if Codec::V2Binary.imp().decode_payload(f.bytes, SCHEMA_VERSION).is_some() {
                    decoded += 1;
                }
            });
        }
        decoded
    });
    rows_out.push(BenchRow {
        name: format!("store/shard_load_eager/{n_records}"),
        median_ms: emed,
        mad_ms: emad,
        reps: t.reps,
    });
    let (smed, smad) = t.measure(|| {
        let store = CacheStore::open(&v2_dir).unwrap();
        store.load_all();
        store.stats().entries
    });
    rows_out.push(BenchRow {
        name: format!("store/shard_load_streaming/{n_records}"),
        median_ms: smed,
        mad_ms: smad,
        reps: t.reps,
    });
    derived.insert("shard_load_speedup".to_string(), emed / smed.max(1e-9));

    // point lookup: sidecar frame fetch vs the deleted-idx scan
    // fallback (which also pays the silent rebuild, as a real reader
    // would)
    let probe = keys[0];
    let (pmed, pmad) = t.measure(|| {
        let store = CacheStore::open(&v2_dir).unwrap();
        store.get_eval(probe).is_some()
    });
    rows_out.push(BenchRow {
        name: "store/point_lookup_sidecar".to_string(),
        median_ms: pmed,
        mad_ms: pmad,
        reps: t.reps,
    });
    let (fmed, fmad) = t.measure(|| {
        for p in fs::read_dir(&v2_dir).unwrap() {
            let p = p.unwrap().path();
            if p.to_string_lossy().ends_with(".idx") {
                let _ = fs::remove_file(p);
            }
        }
        let store = CacheStore::open(&v2_dir).unwrap();
        store.get_eval(probe).is_some()
    });
    rows_out.push(BenchRow {
        name: "store/point_lookup_scan".to_string(),
        median_ms: fmed,
        mad_ms: fmad,
        reps: t.reps,
    });
    derived.insert("point_lookup_speedup".to_string(), fmed / pmed.max(1e-9));

    let _ = fs::remove_dir_all(&base);
    Ok(SuiteReport { suite: "store_v2".to_string(), quick, rows: rows_out, derived })
}

/// The `dse_strategies` suite (ISSUE 8): full-`DseDriver` throughput of
/// every strategy in the zoo on the Axiline-SVM problem under the
/// strict ask/tell cadence, plus the pipelined cadence for the default
/// MOTPE. The derived `pipelined_vs_strict` ratio machine-checks the
/// pipelining claim: overlapping proposal generation with the
/// featurize+score workers must at least match strict alternation at
/// the same seed (the trajectories are byte-identical either way).
fn dse_strategies(quick: bool) -> Result<SuiteReport> {
    use crate::backend::Enablement;
    use crate::coordinator::dse_driver::{axiline_svm_problem, DseDriver, SurrogateBundle};
    use crate::coordinator::{datagen, DatagenConfig, EvalService};
    use crate::dse::{MotpeConfig, StrategyKind};
    use crate::generators::Platform;

    let t = Timer::new(quick);
    let g = datagen::generate(&DatagenConfig {
        n_arch: 6,
        n_backend_train: 8,
        n_backend_test: 2,
        ..DatagenConfig::small(Platform::Axiline, Enablement::Gf12)
    })?;
    let mut runtimes: Vec<f64> = g.dataset.rows.iter().map(|r| r.runtime_s).collect();
    runtimes.sort_by(|a, b| a.total_cmp(b));
    let problem = axiline_svm_problem(
        g.dataset.rows.iter().map(|r| r.power_w).fold(0.0, f64::max) * 2.0,
        runtimes[runtimes.len() * 3 / 4],
    );
    let mk_driver = || {
        let bundle = SurrogateBundle::fit(&g.dataset, &g.backend_split, 7).unwrap();
        DseDriver {
            service: EvalService::new(Enablement::Gf12, 2023).with_surrogate(bundle),
        }
    };
    let scfg = MotpeConfig { n_startup: 16, seed: 5, ..Default::default() };
    let iters = if quick { 48 } else { 96 };

    let mut rows_out: Vec<BenchRow> = Vec::new();
    let mut derived = BTreeMap::new();

    let mut strict_motpe_ms = f64::NAN;
    for kind in StrategyKind::ALL {
        let driver = mk_driver();
        let (med, mad) = t.measure(|| {
            let strategy = kind.build(problem.space(), &scfg);
            driver.run_batched_with(&problem, strategy, iters, 2, 12).unwrap()
        });
        rows_out.push(BenchRow {
            name: format!("dse/strict/{}_x{iters}_b12", kind.name()),
            median_ms: med,
            mad_ms: mad,
            reps: t.reps,
        });
        if kind == StrategyKind::Motpe {
            strict_motpe_ms = med;
        }
    }

    let driver = mk_driver();
    let (pmed, pmad) = t.measure(|| {
        let strategy = StrategyKind::Motpe.build(problem.space(), &scfg);
        driver
            .run_pipelined_with(&problem, strategy, iters, 2, 12, 4)
            .unwrap()
    });
    rows_out.push(BenchRow {
        name: format!("dse/pipelined/motpe_x{iters}_b12_inflight4"),
        median_ms: pmed,
        mad_ms: pmad,
        reps: t.reps,
    });
    derived.insert("pipelined_vs_strict".to_string(), strict_motpe_ms / pmed.max(1e-9));

    Ok(SuiteReport { suite: "dse_strategies".to_string(), quick, rows: rows_out, derived })
}

/// The `fleet` suite (ISSUE 10): a duplicate-heavy oracle sweep under
/// a 16-worker single-flight pool, parked waiters vs work-stealing
/// waiters. Jobs are grouped by key — every worker piles onto the same
/// fresh key at once, the pattern that parks a coalesced pool hardest.
/// The differential check rides along on every run: both modes must
/// agree bit for bit, run the oracle exactly once per unique key, and
/// the stealing pool must actually steal.
fn fleet(quick: bool) -> Result<SuiteReport> {
    use crate::backend::{BackendConfig, Enablement};
    use crate::coordinator::{datagen, EvalService};
    use crate::generators::{ArchConfig, Platform};
    use crate::sampling::SamplerKind;

    let t = Timer::new(quick);
    let uniques = datagen::sample_archs(Platform::Axiline, 6, SamplerKind::Lhs, 21);
    let bcfg = BackendConfig::new(0.9, 0.45);
    let dup = 16usize;
    let jobs: Vec<(ArchConfig, BackendConfig)> = uniques
        .iter()
        .flat_map(|a| std::iter::repeat(a.clone()).take(dup).map(|a| (a, bcfg)))
        .collect();
    let workers = 16usize;
    let parked_svc = || {
        EvalService::new(Enablement::Gf12, 7).with_workers(workers).with_coalescing(true)
    };
    let stealing_svc = || parked_svc().with_work_stealing(true);

    // differential pass before any timing
    let parked = parked_svc();
    let p_out = parked.evaluate_many(&jobs, None)?;
    let stealing = stealing_svc();
    let s_out = stealing.evaluate_many(&jobs, None)?;
    anyhow::ensure!(p_out == s_out, "work-stealing changed evaluation results");
    let (p, s) = (parked.stats(), stealing.stats());
    anyhow::ensure!(
        p.oracle_runs == uniques.len() && s.oracle_runs == uniques.len(),
        "single-flight must run the oracle once per unique key \
         (parked {} / stealing {} != {})",
        p.oracle_runs,
        s.oracle_runs,
        uniques.len()
    );
    anyhow::ensure!(
        s.steals > 0,
        "{workers} workers piling onto duplicate keys must steal at least once"
    );

    let mut rows_out: Vec<BenchRow> = Vec::new();
    let mut derived = BTreeMap::new();
    // fresh service per rep — the oracle memo would otherwise turn
    // every rep after the first into a pure cache sweep
    let (pmed, pmad) = t.measure(|| parked_svc().evaluate_many(&jobs, None).unwrap());
    rows_out.push(BenchRow {
        name: format!("fleet/parked_{}keys_x{dup}dups_w{workers}", uniques.len()),
        median_ms: pmed,
        mad_ms: pmad,
        reps: t.reps,
    });
    let (smed, smad) = t.measure(|| stealing_svc().evaluate_many(&jobs, None).unwrap());
    rows_out.push(BenchRow {
        name: format!("fleet/stealing_{}keys_x{dup}dups_w{workers}", uniques.len()),
        median_ms: smed,
        mad_ms: smad,
        reps: t.reps,
    });
    derived.insert("steal_vs_park".to_string(), pmed / smed.max(1e-9));

    Ok(SuiteReport { suite: "fleet".to_string(), quick, rows: rows_out, derived })
}

/// Comparison outcome: printable lines plus the regressions that
/// should fail the gate.
#[derive(Debug)]
pub struct Comparison {
    pub lines: Vec<String>,
    pub regressions: Vec<String>,
}

/// Diff `candidate` against `baseline`. Timed rows regress when the
/// median grows past `1 + threshold`; derived ratios (higher-better)
/// regress when they drop below `1 - threshold` of the baseline. Rows
/// present in the baseline but missing from the candidate are
/// regressions too (a renamed row must update its baseline
/// deliberately); new candidate rows are reported but never fail.
/// `derived_only` skips the timed rows — the mode for committed seed
/// baselines, whose absolute medians came from another machine.
pub fn compare(
    baseline: &SuiteReport,
    candidate: &SuiteReport,
    threshold: f64,
    derived_only: bool,
) -> Result<Comparison> {
    anyhow::ensure!(
        baseline.suite == candidate.suite,
        "suite mismatch: baseline {:?} vs candidate {:?}",
        baseline.suite,
        candidate.suite
    );
    anyhow::ensure!(threshold > 0.0, "threshold must be positive");
    let mut lines = Vec::new();
    let mut regressions = Vec::new();
    if !derived_only {
        for b in &baseline.rows {
            let Some(c) = candidate.row(&b.name) else {
                regressions
                    .push(format!("{}: in baseline, missing from candidate", b.name));
                continue;
            };
            let ratio = c.median_ms / b.median_ms.max(1e-9);
            let regressed = ratio > 1.0 + threshold;
            lines.push(format!(
                "{:<46} {:>9.3} -> {:>9.3} ms  x{ratio:.2}  {}",
                b.name,
                b.median_ms,
                c.median_ms,
                if regressed { "REGRESSED" } else { "ok" }
            ));
            if regressed {
                regressions.push(format!(
                    "{}: {:.3} ms -> {:.3} ms ({:+.1}%, threshold {:.0}%)",
                    b.name,
                    b.median_ms,
                    c.median_ms,
                    (ratio - 1.0) * 100.0,
                    threshold * 100.0
                ));
            }
        }
        for c in &candidate.rows {
            if baseline.row(&c.name).is_none() {
                lines.push(format!("{:<46} (new row, no baseline)", c.name));
            }
        }
    }
    for (k, b) in &baseline.derived {
        let Some(c) = candidate.derived.get(k) else {
            regressions.push(format!("derived/{k}: missing from candidate"));
            continue;
        };
        let regressed = *c < b * (1.0 - threshold);
        lines.push(format!(
            "derived/{k:<38} {b:>9.3} -> {c:>9.3}  {}",
            if regressed { "REGRESSED" } else { "ok" }
        ));
        if regressed {
            regressions.push(format!(
                "derived/{k}: {b:.3} -> {c:.3} (below the {:.0}% floor)",
                (1.0 - threshold) * 100.0
            ));
        }
    }
    Ok(Comparison { lines, regressions })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(rows: &[(&str, f64)], derived: &[(&str, f64)]) -> SuiteReport {
        SuiteReport {
            suite: "flat_tree".to_string(),
            quick: true,
            rows: rows
                .iter()
                .map(|(n, ms)| BenchRow {
                    name: n.to_string(),
                    median_ms: *ms,
                    mad_ms: 0.01,
                    reps: 5,
                })
                .collect(),
            derived: derived
                .iter()
                .map(|(k, v)| (k.to_string(), *v))
                .collect(),
        }
    }

    #[test]
    fn json_roundtrip_preserves_report() {
        let r = report(
            &[("a/b", 1.25), ("c/d", 0.003)],
            &[("speedup_mega", 3.5), ("router_occupancy", 12.25)],
        );
        let text = r.to_json().to_string();
        let back = SuiteReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn corrupt_reports_read_as_none() {
        for text in [
            "{}",
            r#"{"suite":"x"}"#,
            r#"{"suite":"x","rows":[{"median_ms":1}],"derived":{}}"#,
            r#"{"suite":"x","rows":[],"derived":{"k":"not-a-number"}}"#,
        ] {
            let j = Json::parse(text).unwrap();
            assert!(SuiteReport::from_json(&j).is_none(), "{text}");
        }
    }

    #[test]
    fn within_threshold_passes() {
        let base = report(&[("r", 10.0)], &[("speedup_mega", 3.0)]);
        let cand = report(&[("r", 11.0)], &[("speedup_mega", 2.8)]);
        let cmp = compare(&base, &cand, 0.15, false).unwrap();
        assert!(cmp.regressions.is_empty(), "{:?}", cmp.regressions);
    }

    #[test]
    fn slow_row_regresses_past_threshold() {
        let base = report(&[("r", 10.0)], &[]);
        let cand = report(&[("r", 12.0)], &[]);
        let cmp = compare(&base, &cand, 0.15, false).unwrap();
        assert_eq!(cmp.regressions.len(), 1);
        assert!(cmp.regressions[0].contains("r:"), "{:?}", cmp.regressions);
    }

    #[test]
    fn derived_ratio_drop_regresses() {
        let base = report(&[], &[("speedup_mega", 3.0)]);
        let cand = report(&[], &[("speedup_mega", 2.0)]);
        let cmp = compare(&base, &cand, 0.15, false).unwrap();
        assert_eq!(cmp.regressions.len(), 1);
        // derived checks survive --derived-only; improvements pass
        let cmp = compare(&base, &cand, 0.15, true).unwrap();
        assert_eq!(cmp.regressions.len(), 1);
        let better = report(&[], &[("speedup_mega", 4.0)]);
        assert!(compare(&base, &better, 0.15, true).unwrap().regressions.is_empty());
    }

    #[test]
    fn missing_row_is_a_regression_but_new_rows_pass() {
        let base = report(&[("old", 1.0)], &[]);
        let cand = report(&[("new", 1.0)], &[]);
        let cmp = compare(&base, &cand, 0.15, false).unwrap();
        assert_eq!(cmp.regressions.len(), 1);
        assert!(cmp.regressions[0].contains("missing"));
        // --derived-only ignores the timed rows entirely
        assert!(compare(&base, &cand, 0.15, true).unwrap().regressions.is_empty());
    }

    #[test]
    fn suite_mismatch_is_an_error() {
        let base = report(&[], &[]);
        let mut cand = report(&[], &[]);
        cand.suite = "other".to_string();
        assert!(compare(&base, &cand, 0.15, false).is_err());
    }

    #[test]
    fn invariants_demand_a_mega_speedup() {
        let ok = report(&[], &[("speedup_mega", 1.5)]);
        assert!(check_invariants(&ok).is_ok());
        let slow = report(&[], &[("speedup_mega", 0.8)]);
        assert!(check_invariants(&slow).is_err());
        let missing = report(&[], &[]);
        assert!(check_invariants(&missing).is_err());
        // other suites have no flat_tree invariant
        let mut other = report(&[], &[]);
        other.suite = "something_else".to_string();
        assert!(check_invariants(&other).is_ok());
    }

    #[test]
    fn unknown_suite_is_an_error() {
        assert!(run_suite("no-such-suite", true).is_err());
    }
}
