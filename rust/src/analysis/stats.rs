//! Small statistics helpers shared by experiments and benches.

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn empty_inputs() {
        assert!(mean(&[]).is_nan());
        assert_eq!(std_dev(&[1.0]), 0.0);
        assert!(percentile(&[], 50.0).is_nan());
    }
}
