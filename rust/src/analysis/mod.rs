//! Analysis utilities: t-SNE (Fig. 8) and small statistics helpers.

pub mod stats;
pub mod tsne;

pub use stats::{mean, percentile, std_dev};
pub use tsne::{tsne, TsneConfig};
