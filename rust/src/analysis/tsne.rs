//! Exact t-SNE (Fig. 8: 2-D visualization of GCN graph embeddings).
//! O(n^2) gradient descent with early exaggeration — fine at our scale
//! (hundreds of embeddings).

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct TsneConfig {
    pub perplexity: f64,
    pub iterations: usize,
    pub learning_rate: f64,
    pub seed: u64,
}

impl Default for TsneConfig {
    fn default() -> Self {
        TsneConfig { perplexity: 12.0, iterations: 400, learning_rate: 80.0, seed: 4 }
    }
}

fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b.iter()).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Binary-search the Gaussian bandwidth for one row to hit the target
/// perplexity; returns the row of conditional probabilities.
fn p_row(dists: &[f64], i: usize, perplexity: f64) -> Vec<f64> {
    let n = dists.len();
    let target = perplexity.ln();
    let (mut lo, mut hi) = (1e-10f64, 1e10f64);
    let mut beta = 1.0;
    let mut row = vec![0.0; n];
    for _ in 0..60 {
        let mut sum = 0.0;
        for (j, &d) in dists.iter().enumerate() {
            row[j] = if j == i { 0.0 } else { (-d * beta).exp() };
            sum += row[j];
        }
        let sum = sum.max(1e-300);
        let mut entropy = 0.0;
        for &p in row.iter() {
            let p = p / sum;
            if p > 1e-12 {
                entropy -= p * p.ln();
            }
        }
        if (entropy - target).abs() < 1e-5 {
            break;
        }
        if entropy > target {
            lo = beta;
            beta = if hi >= 1e10 { beta * 2.0 } else { 0.5 * (beta + hi) };
        } else {
            hi = beta;
            beta = 0.5 * (beta + lo);
        }
    }
    let sum: f64 = row.iter().sum::<f64>().max(1e-300);
    row.iter().map(|&p| p / sum).collect()
}

/// Run t-SNE; returns n x 2 coordinates.
pub fn tsne(data: &[Vec<f64>], cfg: TsneConfig) -> Vec<[f64; 2]> {
    let n = data.len();
    if n == 0 {
        return Vec::new();
    }
    if n == 1 {
        return vec![[0.0, 0.0]];
    }
    // symmetrized affinities
    let mut p = vec![vec![0.0; n]; n];
    for i in 0..n {
        let dists: Vec<f64> = (0..n).map(|j| sq_dist(&data[i], &data[j])).collect();
        let row = p_row(&dists, i, cfg.perplexity.min((n as f64 - 1.0) / 3.0));
        for j in 0..n {
            p[i][j] = row[j];
        }
    }
    for i in 0..n {
        for j in (i + 1)..n {
            let v = ((p[i][j] + p[j][i]) / (2.0 * n as f64)).max(1e-12);
            p[i][j] = v;
            p[j][i] = v;
        }
    }

    let mut rng = Rng::new(cfg.seed);
    let mut y: Vec<[f64; 2]> = (0..n).map(|_| [rng.normal() * 1e-2, rng.normal() * 1e-2]).collect();
    let mut vel = vec![[0.0f64; 2]; n];

    for it in 0..cfg.iterations {
        let exaggeration = if it < cfg.iterations / 4 { 6.0 } else { 1.0 };
        let momentum = if it < cfg.iterations / 4 { 0.5 } else { 0.8 };
        // q distribution (student-t)
        let mut q_num = vec![vec![0.0; n]; n];
        let mut q_sum = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                let v = 1.0 / (1.0 + sq_dist(&y[i], &y[j]));
                q_num[i][j] = v;
                q_num[j][i] = v;
                q_sum += 2.0 * v;
            }
        }
        let q_sum = q_sum.max(1e-300);
        for i in 0..n {
            let mut grad = [0.0f64; 2];
            for j in 0..n {
                if i == j {
                    continue;
                }
                let q = (q_num[i][j] / q_sum).max(1e-12);
                let mult = (exaggeration * p[i][j] - q) * q_num[i][j];
                grad[0] += 4.0 * mult * (y[i][0] - y[j][0]);
                grad[1] += 4.0 * mult * (y[i][1] - y[j][1]);
            }
            for d in 0..2 {
                vel[i][d] = momentum * vel[i][d] - cfg.learning_rate * grad[d];
            }
        }
        for i in 0..n {
            y[i][0] += vel[i][0];
            y[i][1] += vel[i][1];
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three well-separated Gaussian blobs in 8-D must stay separated in
    /// the 2-D embedding (cluster preservation, the Fig. 8 property).
    #[test]
    fn preserves_cluster_structure() {
        let mut rng = Rng::new(1);
        let mut data = Vec::new();
        let mut labels = Vec::new();
        for c in 0..3 {
            for _ in 0..15 {
                let center = c as f64 * 20.0;
                data.push((0..8).map(|_| center + rng.normal()).collect::<Vec<f64>>());
                labels.push(c);
            }
        }
        let emb = tsne(&data, TsneConfig { iterations: 250, ..Default::default() });
        // mean intra-cluster distance must be well below inter-cluster
        let mut intra = (0.0, 0);
        let mut inter = (0.0, 0);
        for i in 0..emb.len() {
            for j in (i + 1)..emb.len() {
                let d = ((emb[i][0] - emb[j][0]).powi(2) + (emb[i][1] - emb[j][1]).powi(2)).sqrt();
                if labels[i] == labels[j] {
                    intra = (intra.0 + d, intra.1 + 1);
                } else {
                    inter = (inter.0 + d, inter.1 + 1);
                }
            }
        }
        let intra = intra.0 / intra.1 as f64;
        let inter = inter.0 / inter.1 as f64;
        assert!(inter > 2.0 * intra, "inter {inter} vs intra {intra}");
    }

    #[test]
    fn degenerate_inputs() {
        assert!(tsne(&[], TsneConfig::default()).is_empty());
        assert_eq!(tsne(&[vec![1.0, 2.0]], TsneConfig::default()), vec![[0.0, 0.0]]);
    }

    #[test]
    fn output_is_finite() {
        let data: Vec<Vec<f64>> = (0..20)
            .map(|i| vec![i as f64, (i * i) as f64 * 0.01])
            .collect();
        for p in tsne(&data, TsneConfig { iterations: 100, ..Default::default() }) {
            assert!(p[0].is_finite() && p[1].is_finite());
        }
    }
}
