//! Ridge regression (normal equations + Gaussian elimination with
//! partial pivoting). Serves as the stacked ensemble's meta-learner
//! (paper §5.3: "linear regression acting as meta learner").

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Ridge {
    /// weights[0..d], intercept last.
    pub weights: Vec<f64>,
    pub intercept: f64,
    pub lambda: f64,
}

/// Solve A w = b in place (A is n x n row-major), partial pivoting.
pub fn solve(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Option<Vec<f64>> {
    let n = b.len();
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in (col + 1)..n {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        if a[piv][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        // eliminate
        for r in (col + 1)..n {
            let f = a[r][col] / a[col][col];
            for c in col..n {
                a[r][c] -= f * a[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    // back substitution
    let mut w = vec![0.0; n];
    for col in (0..n).rev() {
        let mut acc = b[col];
        for c in (col + 1)..n {
            acc -= a[col][c] * w[c];
        }
        w[col] = acc / a[col][col];
    }
    Some(w)
}

impl Ridge {
    pub fn fit(x: &[Vec<f64>], y: &[f64], lambda: f64) -> Ridge {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        let d = x[0].len();
        // augmented design: [x, 1]
        let m = d + 1;
        let mut xtx = vec![vec![0.0; m]; m];
        let mut xty = vec![0.0; m];
        for (xi, &yi) in x.iter().zip(y.iter()) {
            for a in 0..m {
                let va = if a < d { xi[a] } else { 1.0 };
                xty[a] += va * yi;
                for b in a..m {
                    let vb = if b < d { xi[b] } else { 1.0 };
                    xtx[a][b] += va * vb;
                }
            }
        }
        for a in 0..m {
            for b in 0..a {
                xtx[a][b] = xtx[b][a];
            }
        }
        // ridge on weights only (not the intercept)
        for (i, row) in xtx.iter_mut().enumerate().take(d) {
            row[i] += lambda;
        }
        let w = solve(xtx, xty).unwrap_or_else(|| vec![0.0; m]);
        Ridge { weights: w[..d].to_vec(), intercept: w[d], lambda }
    }

    pub fn predict_one(&self, x: &[f64]) -> f64 {
        self.intercept
            + self
                .weights
                .iter()
                .zip(x.iter())
                .map(|(w, v)| w * v)
                .sum::<f64>()
    }

    pub fn predict(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        xs.iter().map(|x| self.predict_one(x)).collect()
    }

    /// Model-store serialization (bit-exact prediction replay).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("weights", Json::arr_f64(&self.weights)),
            ("intercept", self.intercept.into()),
            ("lambda", self.lambda.into()),
        ])
    }

    /// Strict inverse of `to_json`: `None` on any defect, so callers
    /// fall back to refitting.
    pub fn from_json(j: &Json) -> Option<Ridge> {
        let weights = j
            .get("weights")
            .as_arr()?
            .iter()
            .map(|v| v.as_f64().filter(|w| w.is_finite()))
            .collect::<Option<Vec<_>>>()?;
        let intercept = j.get("intercept").as_f64()?;
        let lambda = j.get("lambda").as_f64()?;
        if !intercept.is_finite() {
            return None;
        }
        Some(Ridge { weights, intercept, lambda })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_exact_linear_function() {
        let x: Vec<Vec<f64>> = (0..50)
            .map(|i| vec![i as f64 / 10.0, (i * i) as f64 / 100.0])
            .collect();
        let y: Vec<f64> = x.iter().map(|v| 2.0 * v[0] - 3.0 * v[1] + 1.0).collect();
        let m = Ridge::fit(&x, &y, 1e-9);
        assert!((m.weights[0] - 2.0).abs() < 1e-6);
        assert!((m.weights[1] + 3.0).abs() < 1e-6);
        assert!((m.intercept - 1.0).abs() < 1e-6);
    }

    #[test]
    fn lambda_shrinks_weights() {
        let x: Vec<Vec<f64>> = (0..30).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = x.iter().map(|v| 5.0 * v[0]).collect();
        let loose = Ridge::fit(&x, &y, 1e-9);
        let tight = Ridge::fit(&x, &y, 1e6);
        assert!(tight.weights[0].abs() < loose.weights[0].abs());
    }

    #[test]
    fn solver_on_known_system() {
        // 2x + y = 5; x - y = 1  -> x = 2, y = 1
        let a = vec![vec![2.0, 1.0], vec![1.0, -1.0]];
        let b = vec![5.0, 1.0];
        let w = solve(a, b).unwrap();
        assert!((w[0] - 2.0).abs() < 1e-12);
        assert!((w[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn solver_rejects_singular() {
        let a = vec![vec![1.0, 2.0], vec![2.0, 4.0]];
        assert!(solve(a, vec![1.0, 2.0]).is_none());
    }
}
