//! Gradient-boosted decision trees (paper §5.3): least-squares boosting
//! for regression, logistic-loss boosting for the ROI classifier.

use crate::util::json::Json;
use crate::util::rng::Rng;

use super::flat::FlatForest;
use super::tree::{RegTree, TreeParams};

#[derive(Debug, Clone, Copy)]
pub struct GbdtParams {
    pub n_estimators: usize,
    pub learning_rate: f64,
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    /// Row subsample fraction per tree (stochastic gradient boosting).
    pub subsample: f64,
}

impl Default for GbdtParams {
    fn default() -> Self {
        GbdtParams {
            n_estimators: 120,
            learning_rate: 0.08,
            max_depth: 4,
            min_samples_leaf: 2,
            subsample: 0.9,
        }
    }
}

impl GbdtParams {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n_estimators", self.n_estimators.into()),
            ("learning_rate", self.learning_rate.into()),
            ("max_depth", self.max_depth.into()),
            ("min_samples_leaf", self.min_samples_leaf.into()),
            ("subsample", self.subsample.into()),
        ])
    }

    pub fn from_json(j: &Json) -> Option<GbdtParams> {
        Some(GbdtParams {
            n_estimators: j.get("n_estimators").as_usize()?,
            learning_rate: j.get("learning_rate").as_f64()?,
            max_depth: j.get("max_depth").as_usize()?,
            min_samples_leaf: j.get("min_samples_leaf").as_usize()?,
            subsample: j.get("subsample").as_f64()?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct Gbdt {
    pub params: GbdtParams,
    base: f64,
    trees: Vec<RegTree>,
    /// SoA repack of `trees`, built at fit/deserialization time; every
    /// batch prediction routes through it (bit-identical to the
    /// recursive walk — see `models::flat`).
    flat: FlatForest,
}

impl Gbdt {
    pub fn fit(x: &[Vec<f64>], y: &[f64], params: GbdtParams, seed: u64) -> Gbdt {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        let mut rng = Rng::new(seed ^ 0x6BD7);
        let n = x.len();
        let base = y.iter().sum::<f64>() / n as f64;
        let mut pred = vec![base; n];
        let mut trees = Vec::with_capacity(params.n_estimators);
        let tp = TreeParams {
            max_depth: params.max_depth,
            min_samples_leaf: params.min_samples_leaf,
            mtries: None,
        };
        let m = ((n as f64) * params.subsample).ceil() as usize;
        for _ in 0..params.n_estimators {
            let resid: Vec<f64> = y.iter().zip(pred.iter()).map(|(a, p)| a - p).collect();
            let idx = if m >= n {
                (0..n).collect::<Vec<_>>()
            } else {
                rng.choose_k(n, m)
            };
            let tree = RegTree::fit(x, &resid, &idx, tp, &mut rng);
            for (i, p) in pred.iter_mut().enumerate() {
                *p += params.learning_rate * tree.predict(&x[i]);
            }
            trees.push(tree);
        }
        let flat = FlatForest::from_trees(&trees);
        Gbdt { params, base, trees, flat }
    }

    /// Single-row *reference* prediction: the recursive/per-tree walk
    /// the flat batch path must match bit-for-bit. Kept for the
    /// differential tests; batch callers use `predict`/`predict_with`.
    pub fn predict_one(&self, x: &[f64]) -> f64 {
        self.base
            + self.params.learning_rate
                * self.trees.iter().map(|t| t.predict(x)).sum::<f64>()
    }

    pub fn predict(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        self.predict_with(xs, 1)
    }

    /// Batch prediction through the flat SoA forest, row-chunked over
    /// `workers` threads. Bit-identical to mapping `predict_one` (same
    /// per-row addition order) at any worker count.
    pub fn predict_with(&self, xs: &[Vec<f64>], workers: usize) -> Vec<f64> {
        self.flat
            .sum_batch(xs, workers)
            .into_iter()
            .map(|s| self.base + self.params.learning_rate * s)
            .collect()
    }

    /// (flat batch invocations, rows scored) — the call-count
    /// regression tests' probe that batch callers stay batched.
    pub fn flat_stats(&self) -> (usize, usize) {
        self.flat.stats()
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    /// Model-store serialization (bit-exact prediction replay — every
    /// f64 round-trips exactly through `util::json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("params", self.params.to_json()),
            ("base", self.base.into()),
            ("trees", Json::Arr(self.trees.iter().map(|t| t.to_json()).collect())),
        ])
    }

    /// Strict inverse of `to_json`: `None` on any defect, so callers
    /// fall back to refitting.
    pub fn from_json(j: &Json) -> Option<Gbdt> {
        let params = GbdtParams::from_json(j.get("params"))?;
        let base = j.get("base").as_f64()?;
        let trees = j
            .get("trees")
            .as_arr()?
            .iter()
            .map(RegTree::from_json)
            .collect::<Option<Vec<_>>>()?;
        if !base.is_finite() {
            return None;
        }
        let flat = FlatForest::from_trees(&trees);
        Some(Gbdt { params, base, trees, flat })
    }
}

/// Binary classifier via logistic-loss gradient boosting.
#[derive(Debug, Clone)]
pub struct GbdtClassifier {
    params: GbdtParams,
    base: f64, // log-odds
    trees: Vec<RegTree>,
    /// SoA repack of `trees` (see `Gbdt::flat`).
    flat: FlatForest,
}

fn sigmoid(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

impl GbdtClassifier {
    pub fn fit(x: &[Vec<f64>], y: &[bool], params: GbdtParams, seed: u64) -> GbdtClassifier {
        assert_eq!(x.len(), y.len());
        let mut rng = Rng::new(seed ^ 0xC1A5);
        let n = x.len();
        let pos = y.iter().filter(|&&b| b).count() as f64;
        let p0 = (pos / n as f64).clamp(1e-4, 1.0 - 1e-4);
        let base = (p0 / (1.0 - p0)).ln();
        let mut raw = vec![base; n];
        let mut trees = Vec::with_capacity(params.n_estimators);
        let tp = TreeParams {
            max_depth: params.max_depth,
            min_samples_leaf: params.min_samples_leaf,
            mtries: None,
        };
        let m = ((n as f64) * params.subsample).ceil() as usize;
        for _ in 0..params.n_estimators {
            // negative gradient of logloss: y - p
            let grad: Vec<f64> = y
                .iter()
                .zip(raw.iter())
                .map(|(&yi, &r)| (yi as u8 as f64) - sigmoid(r))
                .collect();
            let idx = if m >= n {
                (0..n).collect::<Vec<_>>()
            } else {
                rng.choose_k(n, m)
            };
            let tree = RegTree::fit(x, &grad, &idx, tp, &mut rng);
            for (i, r) in raw.iter_mut().enumerate() {
                *r += params.learning_rate * 4.0 * tree.predict(&x[i]);
            }
            trees.push(tree);
        }
        let flat = FlatForest::from_trees(&trees);
        GbdtClassifier { params, base, trees, flat }
    }

    /// Single-row *reference* probability (recursive per-tree walk);
    /// batch callers use `probs`/`probs_with`, which must match this
    /// bit-for-bit.
    pub fn prob_one(&self, x: &[f64]) -> f64 {
        let raw = self.base
            + self.params.learning_rate
                * 4.0
                * self.trees.iter().map(|t| t.predict(x)).sum::<f64>();
        sigmoid(raw)
    }

    pub fn predict_one(&self, x: &[f64]) -> bool {
        self.prob_one(x) >= 0.5
    }

    /// Batched probabilities through the flat SoA forest — bit-identical
    /// to mapping `prob_one` (same per-row sum, same sigmoid input).
    pub fn probs(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        self.probs_with(xs, 1)
    }

    /// `probs` with row-chunked parallelism (worker-count-invariant).
    pub fn probs_with(&self, xs: &[Vec<f64>], workers: usize) -> Vec<f64> {
        self.flat
            .sum_batch(xs, workers)
            .into_iter()
            .map(|s| sigmoid(self.base + self.params.learning_rate * 4.0 * s))
            .collect()
    }

    pub fn predict(&self, xs: &[Vec<f64>]) -> Vec<bool> {
        self.probs(xs).into_iter().map(|p| p >= 0.5).collect()
    }

    /// (flat batch invocations, rows scored) — call-count probe.
    pub fn flat_stats(&self) -> (usize, usize) {
        self.flat.stats()
    }

    /// Model-store serialization (same layout as the regressor).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("params", self.params.to_json()),
            ("base", self.base.into()),
            ("trees", Json::Arr(self.trees.iter().map(|t| t.to_json()).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Option<GbdtClassifier> {
        let params = GbdtParams::from_json(j.get("params"))?;
        let base = j.get("base").as_f64()?;
        let trees = j
            .get("trees")
            .as_arr()?
            .iter()
            .map(RegTree::from_json)
            .collect::<Option<Vec<_>>>()?;
        if !base.is_finite() {
            return None;
        }
        let flat = FlatForest::from_trees(&trees);
        Some(GbdtClassifier { params, base, trees, flat })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::rmse;

    fn friedman_like(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let v: Vec<f64> = (0..5).map(|_| rng.f64()).collect();
            let t = 10.0 * (std::f64::consts::PI * v[0] * v[1]).sin()
                + 20.0 * (v[2] - 0.5) * (v[2] - 0.5)
                + 10.0 * v[3]
                + 5.0 * v[4];
            x.push(v);
            y.push(t);
        }
        (x, y)
    }

    #[test]
    fn boosting_reduces_training_error_monotonically_ish() {
        let (x, y) = friedman_like(200, 1);
        let few = Gbdt::fit(&x, &y, GbdtParams { n_estimators: 5, ..Default::default() }, 0);
        let many =
            Gbdt::fit(&x, &y, GbdtParams { n_estimators: 120, ..Default::default() }, 0);
        let e_few = rmse(&y, &few.predict(&x));
        let e_many = rmse(&y, &many.predict(&x));
        assert!(e_many < 0.5 * e_few, "{e_many} !< {e_few}/2");
    }

    #[test]
    fn generalizes_on_smooth_function() {
        let (x, y) = friedman_like(400, 2);
        let (xt, yt) = friedman_like(100, 3);
        let m = Gbdt::fit(&x, &y, GbdtParams::default(), 0);
        let e = rmse(&yt, &m.predict(&xt));
        let spread = {
            let mean = yt.iter().sum::<f64>() / yt.len() as f64;
            (yt.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / yt.len() as f64).sqrt()
        };
        assert!(e < 0.45 * spread, "test rmse {e} vs target std {spread}");
    }

    #[test]
    fn classifier_learns_separable_boundary() {
        let mut rng = Rng::new(5);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..300 {
            let a = rng.f64();
            let b = rng.f64();
            x.push(vec![a, b]);
            y.push(a + b > 1.0);
        }
        let m = GbdtClassifier::fit(&x, &y, GbdtParams::default(), 0);
        let acc = x
            .iter()
            .zip(y.iter())
            .filter(|(xi, yi)| m.predict_one(xi) == **yi)
            .count() as f64
            / x.len() as f64;
        assert!(acc > 0.95, "acc={acc}");
    }

    #[test]
    fn classifier_probabilities_are_calibrated_at_extremes() {
        let x: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64 / 100.0]).collect();
        let y: Vec<bool> = (0..100).map(|i| i >= 50).collect();
        let m = GbdtClassifier::fit(&x, &y, GbdtParams::default(), 0);
        assert!(m.prob_one(&[0.05]) < 0.2);
        assert!(m.prob_one(&[0.95]) > 0.8);
    }
}
