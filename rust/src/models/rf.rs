//! Random forest regressor (paper §5.3): bootstrap-bagged CART trees
//! with per-split feature subsampling (`mtries`), predictions averaged.

use crate::util::json::Json;
use crate::util::rng::Rng;

use super::flat::FlatForest;
use super::tree::{RegTree, TreeParams};

#[derive(Debug, Clone, Copy)]
pub struct RfParams {
    pub n_estimators: usize,
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    /// Features per split (None = sqrt(n_features)).
    pub mtries: Option<usize>,
}

impl Default for RfParams {
    fn default() -> Self {
        RfParams { n_estimators: 150, max_depth: 16, min_samples_leaf: 1, mtries: None }
    }
}

impl RfParams {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n_estimators", self.n_estimators.into()),
            ("max_depth", self.max_depth.into()),
            ("min_samples_leaf", self.min_samples_leaf.into()),
            (
                "mtries",
                match self.mtries {
                    Some(m) => m.into(),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Option<RfParams> {
        Some(RfParams {
            n_estimators: j.get("n_estimators").as_usize()?,
            max_depth: j.get("max_depth").as_usize()?,
            min_samples_leaf: j.get("min_samples_leaf").as_usize()?,
            mtries: j.get("mtries").as_usize(),
        })
    }
}

#[derive(Debug, Clone)]
pub struct RandomForest {
    trees: Vec<RegTree>,
    /// SoA repack of `trees`; all batch predictions route through it
    /// (bit-identical to the recursive walk — see `models::flat`).
    flat: FlatForest,
}

impl RandomForest {
    pub fn fit(x: &[Vec<f64>], y: &[f64], params: RfParams, seed: u64) -> RandomForest {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        let n = x.len();
        let n_feat = x[0].len();
        let mtries = params
            .mtries
            .unwrap_or_else(|| (n_feat as f64).sqrt().round() as usize)
            .clamp(1, n_feat);
        let tp = TreeParams {
            max_depth: params.max_depth,
            min_samples_leaf: params.min_samples_leaf,
            mtries: Some(mtries),
        };
        let mut rng = Rng::new(seed ^ 0x2F05E57);
        let trees = (0..params.n_estimators)
            .map(|_| {
                // bootstrap sample (with replacement)
                let idx: Vec<usize> = (0..n).map(|_| rng.below(n)).collect();
                RegTree::fit(x, y, &idx, tp, &mut rng)
            })
            .collect();
        let flat = FlatForest::from_trees(&trees);
        RandomForest { trees, flat }
    }

    /// Single-row *reference* prediction (recursive per-tree walk);
    /// batch callers use `predict`/`predict_with`, which must match
    /// this bit-for-bit.
    pub fn predict_one(&self, x: &[f64]) -> f64 {
        self.trees.iter().map(|t| t.predict(x)).sum::<f64>() / self.trees.len() as f64
    }

    pub fn predict(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        self.predict_with(xs, 1)
    }

    /// Batch prediction through the flat SoA forest (bit-identical to
    /// mapping `predict_one` at any worker count).
    pub fn predict_with(&self, xs: &[Vec<f64>], workers: usize) -> Vec<f64> {
        let n = self.trees.len() as f64;
        self.flat.sum_batch(xs, workers).into_iter().map(|s| s / n).collect()
    }

    /// (flat batch invocations, rows scored) — call-count probe.
    pub fn flat_stats(&self) -> (usize, usize) {
        self.flat.stats()
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    /// Model-store serialization (bit-exact prediction replay).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "trees",
            Json::Arr(self.trees.iter().map(|t| t.to_json()).collect()),
        )])
    }

    /// Strict inverse of `to_json`; an empty forest reads as corrupt
    /// (`predict_one` divides by the tree count).
    pub fn from_json(j: &Json) -> Option<RandomForest> {
        let trees = j
            .get("trees")
            .as_arr()?
            .iter()
            .map(RegTree::from_json)
            .collect::<Option<Vec<_>>>()?;
        if trees.is_empty() {
            return None;
        }
        let flat = FlatForest::from_trees(&trees);
        Some(RandomForest { trees, flat })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::rmse;
    use crate::util::rng::Rng;

    fn noisy_plane(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let v: Vec<f64> = (0..4).map(|_| rng.f64()).collect();
            y.push(3.0 * v[0] - 2.0 * v[1] + 0.05 * rng.normal());
            x.push(v);
        }
        (x, y)
    }

    #[test]
    fn forest_beats_single_deep_tree_on_noise() {
        let (x, y) = noisy_plane(300, 1);
        let (xt, yt) = noisy_plane(100, 2);
        let forest = RandomForest::fit(&x, &y, RfParams::default(), 0);
        let mut rng = Rng::new(0);
        let idx: Vec<usize> = (0..x.len()).collect();
        let single = RegTree::fit(
            &x,
            &y,
            &idx,
            TreeParams { max_depth: 16, min_samples_leaf: 1, mtries: None },
            &mut rng,
        );
        let e_forest = rmse(&yt, &forest.predict(&xt));
        let single_pred: Vec<f64> = xt.iter().map(|v| single.predict(v)).collect();
        let e_single = rmse(&yt, &single_pred);
        assert!(e_forest < e_single, "{e_forest} !< {e_single}");
    }

    #[test]
    fn averaging_smooths_predictions() {
        let (x, y) = noisy_plane(200, 3);
        let m = RandomForest::fit(&x, &y, RfParams::default(), 0);
        // prediction at a midpoint should be near the plane value
        let p = m.predict_one(&[0.5, 0.5, 0.5, 0.5]);
        assert!((p - 0.5).abs() < 0.4, "p={p}");
    }

    #[test]
    fn deterministic_per_seed() {
        let (x, y) = noisy_plane(100, 4);
        let a = RandomForest::fit(&x, &y, RfParams::default(), 9).predict_one(&x[0]);
        let b = RandomForest::fit(&x, &y, RfParams::default(), 9).predict_one(&x[0]);
        assert_eq!(a, b);
    }
}
