//! Learned predictors (paper §5.3): GBDT, Random Forest, ANN, Stacked
//! Ensemble, GCN — plus the two-stage ROI model (§5.4) and the random
//! discrete hyperparameter search (§7.3). ANN/GCN execute on the AOT
//! JAX/Pallas artifacts through the PJRT runtime; the tree family is
//! implemented natively.

pub mod ann;
pub mod ensemble;
pub mod flat;
pub mod gbdt;
pub mod gcn;
pub mod linear;
pub mod rf;
pub mod tree;
pub mod tuning;
pub mod two_stage;

pub use ann::{AnnModel, TrainConfig};
pub use ensemble::{BasePredictions, StackedEnsemble};
pub use flat::FlatForest;
pub use gbdt::{Gbdt, GbdtClassifier, GbdtParams};
pub use gcn::{GcnModel, GraphCache};
pub use linear::Ridge;
pub use rf::{RandomForest, RfParams};
pub use tree::{RegTree, TreeParams};
pub use tuning::{get_node_config, tune_gbdt, tune_rf, SearchBudget, TunedGbdt, TunedRf};
pub use two_stage::{RoiClassifier, TwoStageModel};

/// Uniform interface over feature-based regressors (the GCN, which needs
/// graph inputs, has its own `predict_rows` API on `GcnModel`).
pub trait Predictor {
    fn model_name(&self) -> &'static str;
    fn predict_xs(&self, xs: &[Vec<f64>]) -> anyhow::Result<Vec<f64>>;
}

impl Predictor for Gbdt {
    fn model_name(&self) -> &'static str {
        "GBDT"
    }
    fn predict_xs(&self, xs: &[Vec<f64>]) -> anyhow::Result<Vec<f64>> {
        Ok(self.predict(xs))
    }
}

impl Predictor for RandomForest {
    fn model_name(&self) -> &'static str {
        "RF"
    }
    fn predict_xs(&self, xs: &[Vec<f64>]) -> anyhow::Result<Vec<f64>> {
        Ok(self.predict(xs))
    }
}

impl Predictor for AnnModel {
    fn model_name(&self) -> &'static str {
        "ANN"
    }
    fn predict_xs(&self, xs: &[Vec<f64>]) -> anyhow::Result<Vec<f64>> {
        self.predict(xs)
    }
}

impl Predictor for Ridge {
    fn model_name(&self) -> &'static str {
        "Ridge"
    }
    fn predict_xs(&self, xs: &[Vec<f64>]) -> anyhow::Result<Vec<f64>> {
        Ok(self.predict(xs))
    }
}
