//! ANN predictor backed by the AOT-compiled JAX/Pallas artifacts.
//!
//! Training runs entirely in rust: the `train_epoch` executable folds
//! `EPOCH_STEPS` Adam steps into one PJRT call (L2's lax.scan), and the
//! rust side owns shuffling, batching/padding, the decaying-LR +
//! patience schedule and early stopping (paper §7.3), and best-theta
//! checkpointing by validation muAPE.

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::runtime::{Batcher, Engine, ModelArch, Variant};
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

#[derive(Debug, Clone, Copy)]
pub struct TrainConfig {
    pub max_epochs: usize,
    pub lr0: f32,
    /// LR decay factor on validation plateau (paper: 0.7).
    pub decay: f32,
    /// Plateau patience in epochs before decaying (paper: 5).
    pub patience: usize,
    /// Early stop after this many epochs without improvement (paper: 20).
    pub early_stop: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            max_epochs: 160,
            lr0: 6e-3,
            decay: 0.7,
            patience: 5,
            early_stop: 20,
            seed: 17,
        }
    }
}

/// Glorot-uniform init of the flat parameter vector, mirroring
/// python model.glorot_init's scheme (weights U(+-sqrt(6/(fi+fo))),
/// biases zero).
pub fn glorot_init(variant: &Variant, rng: &mut Rng) -> Tensor {
    let mut theta = vec![0.0f32; variant.param_total];
    for e in &variant.param_layout {
        if e.shape.len() == 2 {
            let limit = (6.0 / (e.shape[0] + e.shape[1]) as f64).sqrt();
            let size = e.shape[0] * e.shape[1];
            for i in 0..size {
                theta[e.offset + i] = rng.range(-limit, limit) as f32;
            }
        }
    }
    Tensor::from_vec(&[variant.param_total], theta).unwrap()
}

pub struct AnnModel {
    engine: Rc<Engine>,
    pub variant: String,
    pub cfg: TrainConfig,
    theta: Option<Tensor>,
    y_scale: f64,
    pub history: Vec<f64>,
    pub best_val_mu_ape: f64,
}

impl AnnModel {
    pub fn new(engine: Rc<Engine>, variant: &str, cfg: TrainConfig) -> Result<AnnModel> {
        let v = engine.manifest.variant(variant)?;
        anyhow::ensure!(matches!(v.arch, ModelArch::Ann { .. }), "{variant} is not an ANN");
        Ok(AnnModel {
            engine,
            variant: variant.to_string(),
            cfg,
            theta: None,
            y_scale: 1.0,
            history: Vec::new(),
            best_val_mu_ape: f64::INFINITY,
        })
    }

    fn dims(&self) -> (usize, usize, usize) {
        let m = &self.engine.manifest;
        (m.batch, m.feat, m.epoch_steps)
    }

    /// Pack `idx` rows into [S, B, F] + [S, B] + [S, B] tensors, padding
    /// incomplete batches with weight-0 rows.
    fn pack_epoch_chunk(
        &self,
        x: &[Vec<f64>],
        y: &[f64],
        idx: &[usize],
    ) -> (Tensor, Tensor, Tensor) {
        let (b, f, s) = self.dims();
        let mut xs = vec![0.0f32; s * b * f];
        let mut ys = vec![0.0f32; s * b];
        let mut ws = vec![0.0f32; s * b];
        for (slot, &i) in idx.iter().enumerate() {
            debug_assert!(slot < s * b);
            for (j, &v) in x[i].iter().enumerate().take(f) {
                xs[slot * f + j] = v as f32;
            }
            ys[slot] = (y[i] / self.y_scale) as f32;
            ws[slot] = 1.0;
        }
        (
            Tensor::from_vec(&[s, b, f], xs).unwrap(),
            Tensor::from_vec(&[s, b], ys).unwrap(),
            Tensor::from_vec(&[s, b], ws).unwrap(),
        )
    }

    /// Train on (x, y); validation drives LR decay + early stopping.
    pub fn fit(
        &mut self,
        x: &[Vec<f64>],
        y: &[f64],
        x_val: &[Vec<f64>],
        y_val: &[f64],
    ) -> Result<()> {
        anyhow::ensure!(!x.is_empty() && x.len() == y.len(), "bad training set");
        let (b, _, s) = self.dims();
        let chunk_rows = s * b;
        self.y_scale = (y.iter().map(|v| v.abs()).sum::<f64>() / y.len() as f64).max(1e-12);

        let v = self.engine.manifest.variant(&self.variant)?.clone();
        let epoch_file = v.entrypoint("train_epoch")?.file.clone();
        let mut rng = Rng::new(self.cfg.seed);
        let mut theta = glorot_init(&v, &mut rng);
        let p = v.param_total;
        let mut m = Tensor::zeros(&[p]);
        let mut vv = Tensor::zeros(&[p]);
        let mut t_step = 0f32;
        let mut lr = self.cfg.lr0;

        let mut best_theta = theta.clone();
        let mut best_val = f64::INFINITY;
        let mut since_improve = 0usize;
        let mut since_decay = 0usize;
        self.history.clear();

        let mut order: Vec<usize> = (0..x.len()).collect();
        for _epoch in 0..self.cfg.max_epochs {
            rng.shuffle(&mut order);
            for chunk in order.chunks(chunk_rows) {
                let (xs, ys, ws) = self.pack_epoch_chunk(x, y, chunk);
                let out = self.engine.run(
                    &epoch_file,
                    &[
                        theta,
                        m,
                        vv,
                        Tensor::scalar(t_step + 1.0),
                        Tensor::scalar(lr),
                        xs,
                        ys,
                        ws,
                    ],
                )?;
                let mut it = out.into_iter();
                theta = it.next().context("theta out")?;
                m = it.next().context("m out")?;
                vv = it.next().context("v out")?;
                t_step += s as f32;
            }

            // validation muAPE with the current theta
            self.theta = Some(theta.clone());
            let val_pred = self.predict(x_val)?;
            let val = crate::metrics::mape_stats(y_val, &val_pred).mu_ape;
            self.history.push(val);
            if val < best_val - 1e-9 {
                best_val = val;
                best_theta = theta.clone();
                since_improve = 0;
                since_decay = 0;
            } else {
                since_improve += 1;
                since_decay += 1;
                if since_decay >= self.cfg.patience {
                    lr *= self.cfg.decay;
                    since_decay = 0;
                }
                if since_improve >= self.cfg.early_stop {
                    break;
                }
            }
        }
        self.theta = Some(best_theta);
        self.best_val_mu_ape = best_val;
        Ok(())
    }

    pub fn predict(&self, xs: &[Vec<f64>]) -> Result<Vec<f64>> {
        let theta = self.theta.as_ref().context("model not fitted")?;
        let (b, f, _) = self.dims();
        let v = self.engine.manifest.variant(&self.variant)?;
        let file = &v.entrypoint("predict")?.file;
        let batcher = Batcher::new(b);
        let rows: Vec<Vec<f32>> = xs
            .iter()
            .map(|r| {
                let mut out = vec![0.0f32; f];
                for (j, &val) in r.iter().enumerate().take(f) {
                    out[j] = val as f32;
                }
                out
            })
            .collect();
        let mut result = vec![0.0f32; xs.len()];
        for plan in batcher.plan(xs.len()) {
            let mut packed = vec![0.0f32; b * f];
            for (slot, &src) in plan.rows.iter().enumerate() {
                packed[slot * f..(slot + 1) * f].copy_from_slice(&rows[src]);
            }
            let x_t = Tensor::from_vec(&[b, f], packed).unwrap();
            let out = self.engine.run(file, &[theta.clone(), x_t])?;
            batcher.unpack(&plan, out[0].data(), &mut result);
        }
        Ok(result.into_iter().map(|p| p as f64 * self.y_scale).collect())
    }
}
