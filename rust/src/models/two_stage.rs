//! The two-stage model (paper §5.4): stage 1 classifies whether a
//! configuration lands in the region of interest (Eq. 4); stage 2
//! regressors — trained only on ROI points — predict PPA/system metrics
//! for points the classifier accepts. Out-of-ROI points are discarded,
//! which is what keeps the noisy flow extremes from poisoning the
//! regressors.

use anyhow::Result;

use crate::metrics::{classify_stats, ClassifyStats};
use crate::util::json::Json;

use super::gbdt::{GbdtClassifier, GbdtParams};

pub struct RoiClassifier {
    model: GbdtClassifier,
}

impl RoiClassifier {
    pub fn fit(x: &[Vec<f64>], in_roi: &[bool], seed: u64) -> RoiClassifier {
        let params = GbdtParams {
            n_estimators: 150,
            learning_rate: 0.1,
            max_depth: 4,
            min_samples_leaf: 2,
            subsample: 0.9,
        };
        RoiClassifier { model: GbdtClassifier::fit(x, in_roi, params, seed) }
    }

    pub fn predict(&self, xs: &[Vec<f64>]) -> Vec<bool> {
        self.model.predict(xs)
    }

    /// Single-row *reference* probability (recursive walk). Batch
    /// callers must use `probs`/`probs_with` — falling back to per-row
    /// `prob` loops was the pointer-chasing hot spot the flat layout
    /// removes (the call-count regression test pins this).
    pub fn prob(&self, x: &[f64]) -> f64 {
        self.model.prob_one(x)
    }

    /// Batched ROI probabilities through the flat SoA forest
    /// (bit-identical to mapping `prob`).
    pub fn probs(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        self.model.probs(xs)
    }

    /// `probs` with row-chunked parallelism (worker-count-invariant).
    pub fn probs_with(&self, xs: &[Vec<f64>], workers: usize) -> Vec<f64> {
        self.model.probs_with(xs, workers)
    }

    /// (flat batch invocations, rows scored) — call-count probe.
    pub fn flat_stats(&self) -> (usize, usize) {
        self.model.flat_stats()
    }

    pub fn evaluate(&self, xs: &[Vec<f64>], actual: &[bool]) -> ClassifyStats {
        classify_stats(actual, &self.predict(xs))
    }

    /// Model-store serialization (bit-exact prediction replay).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![("model", self.model.to_json())])
    }

    /// Strict inverse of `to_json`: `None` on any defect, so callers
    /// fall back to refitting.
    pub fn from_json(j: &Json) -> Option<RoiClassifier> {
        Some(RoiClassifier { model: GbdtClassifier::from_json(j.get("model"))? })
    }
}

/// Stage-1 + stage-2 bundle for one metric; generic over the regressor
/// (the experiments instantiate it with each of the five model kinds).
pub struct TwoStageModel<R> {
    pub classifier: RoiClassifier,
    pub regressor: R,
}

pub struct TwoStagePrediction {
    /// Predicted value for rows the classifier accepted; None = discarded.
    pub values: Vec<Option<f64>>,
    pub accepted: usize,
}

impl<R> TwoStageModel<R> {
    /// Predict with the ROI gate: classifier-rejected rows get None.
    pub fn predict_gated(
        &self,
        xs: &[Vec<f64>],
        predict: impl Fn(&R, &[Vec<f64>]) -> Result<Vec<f64>>,
    ) -> Result<TwoStagePrediction> {
        let gate = self.classifier.predict(xs);
        let kept: Vec<usize> =
            gate.iter().enumerate().filter(|(_, &g)| g).map(|(i, _)| i).collect();
        let kept_x: Vec<Vec<f64>> = kept.iter().map(|&i| xs[i].clone()).collect();
        let preds = if kept_x.is_empty() {
            Vec::new()
        } else {
            predict(&self.regressor, &kept_x)?
        };
        let mut values = vec![None; xs.len()];
        for (j, &i) in kept.iter().enumerate() {
            values[i] = Some(preds[j]);
        }
        Ok(TwoStagePrediction { accepted: kept.len(), values })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// ROI = band 0.3 <= x0 <= 0.7 (like f_target within the ROI band).
    fn band_data(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<bool>) {
        let mut rng = Rng::new(seed);
        let x: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.f64(), rng.f64()]).collect();
        let y = x.iter().map(|v| (0.3..=0.7).contains(&v[0])).collect();
        (x, y)
    }

    #[test]
    fn classifier_learns_roi_band() {
        let (x, y) = band_data(400, 1);
        let (xt, yt) = band_data(200, 2);
        let c = RoiClassifier::fit(&x, &y, 0);
        let stats = c.evaluate(&xt, &yt);
        assert!(stats.accuracy > 0.93, "{stats:?}");
        assert!(stats.f1 > 0.9, "{stats:?}");
    }

    #[test]
    fn gated_prediction_discards_rejects() {
        let (x, y) = band_data(300, 3);
        let c = RoiClassifier::fit(&x, &y, 0);
        let model = TwoStageModel { classifier: c, regressor: () };
        let (xt, _) = band_data(50, 4);
        let out = model
            .predict_gated(&xt, |_, rows| Ok(vec![1.0; rows.len()]))
            .unwrap();
        assert_eq!(out.values.len(), 50);
        let some = out.values.iter().filter(|v| v.is_some()).count();
        assert_eq!(some, out.accepted);
        assert!(some > 5 && some < 45, "gate should be selective: {some}");
    }
}
