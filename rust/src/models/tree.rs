//! CART regression tree: the base learner under both GBDT and RF.
//! Flattened node array (cache-friendly, branch-light evaluation),
//! variance-reduction splits, optional per-split feature subsampling
//! (`mtries`, used by random forest).

use crate::util::json::Json;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    /// Features considered per split (None = all) — RF's `mtries`.
    pub mtries: Option<usize>,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams { max_depth: 6, min_samples_leaf: 2, mtries: None }
    }
}

/// One tree node; `pub(crate)` so `models::flat` can repack fitted /
/// deserialized trees into its contiguous SoA slabs without a copy of
/// the validation logic (both constructors below enforce the pre-order
/// child invariant the flat walker relies on).
#[derive(Debug, Clone, Copy)]
pub(crate) struct Node {
    /// Split feature (leaf if usize::MAX).
    pub(crate) feature: usize,
    pub(crate) threshold: f64,
    /// Index of left child (pre-order: always parent + 1).
    pub(crate) left: u32,
    /// Index of right child (start of the right subtree). Stored
    /// explicitly: deriving it by walking the left subtree made
    /// prediction O(tree) per *step* — the profile's top hot spot.
    pub(crate) right: u32,
    /// Leaf prediction.
    pub(crate) value: f64,
}

#[derive(Debug, Clone)]
pub struct RegTree {
    nodes: Vec<Node>,
}

struct Builder<'a> {
    x: &'a [Vec<f64>],
    y: &'a [f64],
    params: TreeParams,
    rng: &'a mut Rng,
    nodes: Vec<Node>,
}

impl<'a> Builder<'a> {
    /// Best (feature, threshold, score) via exhaustive scan over sorted
    /// feature values; score = variance reduction (SSE decrease).
    fn best_split(&mut self, idx: &[usize]) -> Option<(usize, f64)> {
        let n_feat = self.x[0].len();
        let k = self.params.mtries.unwrap_or(n_feat).min(n_feat);
        // Sampled subset first; if it yields no valid split, fall back to
        // the remaining features (sklearn-style) so a node that drew only
        // constant features does not become a premature leaf.
        let mut feats = if k == n_feat {
            (0..n_feat).collect::<Vec<_>>()
        } else {
            let chosen = self.rng.choose_k(n_feat, k);
            let rest: Vec<usize> = (0..n_feat).filter(|f| !chosen.contains(f)).collect();
            let mut all = chosen;
            all.extend(rest);
            all
        };
        feats.truncate(n_feat);
        let primary_k = k;

        let total_sum: f64 = idx.iter().map(|&i| self.y[i]).sum();
        let total_sq: f64 = idx.iter().map(|&i| self.y[i] * self.y[i]).sum();
        let n = idx.len() as f64;
        let base_sse = total_sq - total_sum * total_sum / n;

        let mut best: Option<(usize, f64, f64)> = None; // (feat, thr, sse)
        let mut order: Vec<usize> = idx.to_vec();
        for (fi, f) in feats.into_iter().enumerate() {
            // stop at the sampled budget once any valid split was found
            if fi >= primary_k && best.is_some() {
                break;
            }
            // total_cmp, not partial_cmp().unwrap(): a NaN feature
            // (reachable since the null-sentinel JSON round-trip reads
            // non-finite values back as NaN) used to panic here. NaNs
            // sort last under the IEEE total order.
            order.sort_unstable_by(|&a, &b| self.x[a][f].total_cmp(&self.x[b][f]));
            let mut left_sum = 0.0;
            let mut left_sq = 0.0;
            for (pos, &i) in order.iter().enumerate().take(order.len() - 1) {
                left_sum += self.y[i];
                left_sq += self.y[i] * self.y[i];
                let nl = (pos + 1) as f64;
                let nr = n - nl;
                // can't split between equal feature values
                if self.x[i][f] == self.x[order[pos + 1]][f] {
                    continue;
                }
                if (pos + 1) < self.params.min_samples_leaf
                    || (order.len() - pos - 1) < self.params.min_samples_leaf
                {
                    continue;
                }
                let right_sum = total_sum - left_sum;
                let right_sq = total_sq - left_sq;
                let sse = (left_sq - left_sum * left_sum / nl)
                    + (right_sq - right_sum * right_sum / nr);
                if best.map(|(_, _, s)| sse < s).unwrap_or(sse < base_sse - 1e-12) {
                    let thr = 0.5 * (self.x[i][f] + self.x[order[pos + 1]][f]);
                    // a NaN neighbour yields a NaN midpoint: not a
                    // usable threshold (x <= NaN is always false)
                    if thr.is_finite() {
                        best = Some((f, thr, sse));
                    }
                }
            }
        }
        best.map(|(f, t, _)| (f, t))
    }

    fn build(&mut self, idx: &mut Vec<usize>, depth: usize) -> u32 {
        let node_id = self.nodes.len() as u32;
        let n = idx.len() as f64;
        let mean = idx.iter().map(|&i| self.y[i]).sum::<f64>() / n;
        self.nodes.push(Node {
            feature: usize::MAX,
            threshold: 0.0,
            left: 0,
            right: 0,
            value: mean,
        });

        if depth >= self.params.max_depth || idx.len() < 2 * self.params.min_samples_leaf {
            return node_id;
        }
        let Some((f, thr)) = self.best_split(idx) else {
            return node_id;
        };
        let (mut l, mut r): (Vec<usize>, Vec<usize>) =
            idx.iter().partition(|&&i| self.x[i][f] <= thr);
        if l.is_empty() || r.is_empty() {
            return node_id;
        }
        let left_id = self.build(&mut l, depth + 1);
        let right_id = self.build(&mut r, depth + 1);
        let node = &mut self.nodes[node_id as usize];
        node.feature = f;
        node.threshold = thr;
        node.left = left_id;
        node.right = right_id;
        node_id
    }
}

impl RegTree {
    pub fn fit(
        x: &[Vec<f64>],
        y: &[f64],
        idx: &[usize],
        params: TreeParams,
        rng: &mut Rng,
    ) -> RegTree {
        assert!(!idx.is_empty(), "empty training set");
        let mut b = Builder { x, y, params, rng, nodes: Vec::new() };
        let mut idx = idx.to_vec();
        b.build(&mut idx, 0);
        RegTree { nodes: b.nodes }
    }

    pub fn depth(&self) -> usize {
        fn d(nodes: &[Node], i: usize) -> usize {
            let n = nodes[i];
            if n.feature == usize::MAX {
                1
            } else {
                1 + d(nodes, n.left as usize).max(d(nodes, n.right as usize))
            }
        }
        d(&self.nodes, 0)
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Validated node slab (pre-order, children strictly after their
    /// parent) — what `models::flat::FlatForest::from_trees` packs.
    pub(crate) fn nodes(&self) -> &[Node] {
        &self.nodes
    }
}

impl RegTree {
    /// Iterative prediction: one array lookup per level. This is the
    /// *reference walker*: `models::flat` batch inference must match it
    /// bit-for-bit (the differential property tests in
    /// `tests/flat_tree.rs` pin that), including the NaN routing below
    /// (`x <= thr` is false for NaN, so NaN features go right).
    #[inline]
    pub fn predict(&self, x: &[f64]) -> f64 {
        let mut cur = 0usize;
        loop {
            let n = unsafe { self.nodes.get_unchecked(cur) };
            if n.feature == usize::MAX {
                return n.value;
            }
            cur = if x[n.feature] <= n.threshold {
                n.left as usize
            } else {
                n.right as usize
            };
        }
    }

    /// Model-store serialization: one `[feature, threshold, left,
    /// right, value]` row per node (leaf = feature -1). f64 fields
    /// round-trip bit-exactly through `util::json`, so a deserialized
    /// tree replays identical predictions.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.nodes
                .iter()
                .map(|n| {
                    Json::Arr(vec![
                        Json::Num(if n.feature == usize::MAX {
                            -1.0
                        } else {
                            n.feature as f64
                        }),
                        Json::Num(n.threshold),
                        Json::Num(n.left as f64),
                        Json::Num(n.right as f64),
                        Json::Num(n.value),
                    ])
                })
                .collect(),
        )
    }

    /// Strict inverse of `to_json`: any structural defect reads as
    /// corrupt (`None`), so callers fall back to refitting. Beyond
    /// field presence/finiteness, internal nodes must point *forward*
    /// (`left`/`right` strictly greater than their own index, within
    /// range) — the pre-order layout `build` emits — which guarantees
    /// `predict`'s unchecked walk terminates and never escapes the
    /// node array; the feature index is also sanity-capped so a
    /// corrupt artifact cannot turn prediction into an out-of-bounds
    /// row access.
    pub fn from_json(j: &Json) -> Option<RegTree> {
        // no real feature space comes close to this; anything above
        // is a corrupt artifact, not a model
        const MAX_FEATURE: f64 = (1u32 << 20) as f64;
        let arr = j.as_arr()?;
        if arr.is_empty() {
            return None;
        }
        let mut nodes = Vec::with_capacity(arr.len());
        for (pos, row) in arr.iter().enumerate() {
            let row = row.as_arr()?;
            if row.len() != 5 {
                return None;
            }
            let feat = row[0].as_f64()?;
            let threshold = row[1].as_f64()?;
            let left = row[2].as_f64()?;
            let right = row[3].as_f64()?;
            let value = row[4].as_f64()?;
            if !threshold.is_finite() || !value.is_finite() {
                return None;
            }
            let is_leaf = feat < 0.0;
            if !is_leaf {
                if feat >= MAX_FEATURE {
                    return None;
                }
                // pre-order invariant: children live strictly after
                // their parent (rules out cycles and self-references)
                let lo = (pos + 1) as f64;
                let hi = arr.len() as f64;
                if left < lo || right < lo || left >= hi || right >= hi {
                    return None;
                }
            }
            nodes.push(Node {
                feature: if is_leaf { usize::MAX } else { feat as usize },
                threshold,
                left: left as u32,
                right: right as u32,
                value,
            });
        }
        Some(RegTree { nodes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Greedy-learnable two-level step function (NB: XOR would be the
    /// canonical greedy-CART failure — zero first-split gain — so we
    /// test on an additive target instead).
    fn step_data() -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..40 {
            let a = (i % 2) as f64;
            let b = ((i / 2) % 2) as f64;
            x.push(vec![a + 0.01 * (i as f64 / 40.0), b]);
            y.push(2.0 * a + b);
        }
        (x, y)
    }

    #[test]
    fn fits_two_level_step_exactly() {
        let (x, y) = step_data();
        let idx: Vec<usize> = (0..x.len()).collect();
        let mut rng = Rng::new(0);
        let t = RegTree::fit(&x, &y, &idx, TreeParams::default(), &mut rng);
        for (xi, yi) in x.iter().zip(y.iter()) {
            assert!((t.predict(xi) - yi).abs() < 1e-9);
        }
    }

    #[test]
    fn respects_max_depth() {
        let (x, y) = step_data();
        let idx: Vec<usize> = (0..x.len()).collect();
        let mut rng = Rng::new(0);
        let stump = RegTree::fit(
            &x,
            &y,
            &idx,
            TreeParams { max_depth: 1, ..Default::default() },
            &mut rng,
        );
        assert!(stump.depth() <= 2);
    }

    #[test]
    fn constant_target_single_leaf() {
        let x: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64]).collect();
        let y = vec![5.0; 10];
        let idx: Vec<usize> = (0..10).collect();
        let mut rng = Rng::new(0);
        let t = RegTree::fit(&x, &y, &idx, TreeParams::default(), &mut rng);
        assert_eq!(t.node_count(), 1);
        assert_eq!(t.predict(&[3.0]), 5.0);
    }

    #[test]
    fn min_samples_leaf_enforced() {
        let x: Vec<Vec<f64>> = (0..9).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..9).map(|i| i as f64).collect();
        let idx: Vec<usize> = (0..9).collect();
        let mut rng = Rng::new(0);
        let t = RegTree::fit(
            &x,
            &y,
            &idx,
            TreeParams { max_depth: 10, min_samples_leaf: 4, mtries: None },
            &mut rng,
        );
        // with min leaf 4 and 9 points, at most one split
        assert!(t.node_count() <= 3);
    }

    #[test]
    fn nan_feature_rows_do_not_panic() {
        // ISSUE 3 satellite regression: sorting feature values with
        // partial_cmp().unwrap() panicked on a NaN feature (reachable
        // since PR 2's as_f64_or_nan reads null-sentinel JSON as NaN)
        let (mut x, y) = step_data();
        x[3][0] = f64::NAN;
        x[17][1] = f64::NAN;
        let idx: Vec<usize> = (0..x.len()).collect();
        let mut rng = Rng::new(0);
        let t = RegTree::fit(&x, &y, &idx, TreeParams::default(), &mut rng);
        // a NaN query routes right at every split and lands in a leaf
        assert!(t.predict(&[f64::NAN, f64::NAN]).is_finite());
        // the clean rows still fit well
        let clean: Vec<usize> = (0..x.len()).filter(|&i| i != 3 && i != 17).collect();
        let err: f64 = clean
            .iter()
            .map(|&i| (t.predict(&x[i]) - y[i]).abs())
            .sum::<f64>()
            / clean.len() as f64;
        assert!(err < 0.5, "mean abs err {err}");
    }

    #[test]
    fn json_roundtrip_replays_identical_predictions() {
        let (x, y) = step_data();
        let idx: Vec<usize> = (0..x.len()).collect();
        let mut rng = Rng::new(2);
        let t = RegTree::fit(&x, &y, &idx, TreeParams::default(), &mut rng);
        let text = t.to_json().to_string();
        let back = RegTree::from_json(&crate::util::json::Json::parse(&text).unwrap())
            .expect("round-trip");
        for xi in &x {
            assert_eq!(t.predict(xi).to_bits(), back.predict(xi).to_bits());
        }
        // structural corruption reads as None, never a bad tree
        let corrupt = |s: &str| {
            RegTree::from_json(&crate::util::json::Json::parse(s).unwrap()).is_none()
        };
        assert!(corrupt("[]"));
        assert!(corrupt("[[0,0.5,9,9,1.0]]"), "child index out of range");
        assert!(
            corrupt("[[0,0.5,0,0,1.0]]"),
            "self-referential node would make predict() loop forever"
        );
        assert!(
            corrupt("[[0,0.5,1,2,0],[0,0.5,0,2,1],[-1,0,0,0,2]]"),
            "backward child edge (node 1 -> node 0) would cycle"
        );
        assert!(
            corrupt("[[9999999,0.5,1,2,0],[-1,0,0,0,1],[-1,0,0,0,2]]"),
            "absurd feature index would index out of the row at predict time"
        );
    }

    #[test]
    fn mtries_subsampling_still_learns() {
        let (x, y) = step_data();
        let idx: Vec<usize> = (0..x.len()).collect();
        let mut rng = Rng::new(1);
        let t = RegTree::fit(
            &x,
            &y,
            &idx,
            TreeParams { max_depth: 6, min_samples_leaf: 1, mtries: Some(1) },
            &mut rng,
        );
        let correct = x
            .iter()
            .zip(y.iter())
            .filter(|(xi, yi)| (t.predict(xi) - **yi).abs() < 0.5)
            .count();
        assert!(correct >= 30, "{correct}/40");
    }
}
