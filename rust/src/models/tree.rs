//! CART regression tree: the base learner under both GBDT and RF.
//! Flattened node array (cache-friendly, branch-light evaluation),
//! variance-reduction splits, optional per-split feature subsampling
//! (`mtries`, used by random forest).

use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    /// Features considered per split (None = all) — RF's `mtries`.
    pub mtries: Option<usize>,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams { max_depth: 6, min_samples_leaf: 2, mtries: None }
    }
}

#[derive(Debug, Clone, Copy)]
struct Node {
    /// Split feature (leaf if usize::MAX).
    feature: usize,
    threshold: f64,
    /// Index of left child (pre-order: always parent + 1).
    left: u32,
    /// Index of right child (start of the right subtree). Stored
    /// explicitly: deriving it by walking the left subtree made
    /// prediction O(tree) per *step* — the profile's top hot spot.
    right: u32,
    /// Leaf prediction.
    value: f64,
}

#[derive(Debug, Clone)]
pub struct RegTree {
    nodes: Vec<Node>,
}

struct Builder<'a> {
    x: &'a [Vec<f64>],
    y: &'a [f64],
    params: TreeParams,
    rng: &'a mut Rng,
    nodes: Vec<Node>,
}

impl<'a> Builder<'a> {
    /// Best (feature, threshold, score) via exhaustive scan over sorted
    /// feature values; score = variance reduction (SSE decrease).
    fn best_split(&mut self, idx: &[usize]) -> Option<(usize, f64)> {
        let n_feat = self.x[0].len();
        let k = self.params.mtries.unwrap_or(n_feat).min(n_feat);
        // Sampled subset first; if it yields no valid split, fall back to
        // the remaining features (sklearn-style) so a node that drew only
        // constant features does not become a premature leaf.
        let mut feats = if k == n_feat {
            (0..n_feat).collect::<Vec<_>>()
        } else {
            let chosen = self.rng.choose_k(n_feat, k);
            let rest: Vec<usize> = (0..n_feat).filter(|f| !chosen.contains(f)).collect();
            let mut all = chosen;
            all.extend(rest);
            all
        };
        feats.truncate(n_feat);
        let primary_k = k;

        let total_sum: f64 = idx.iter().map(|&i| self.y[i]).sum();
        let total_sq: f64 = idx.iter().map(|&i| self.y[i] * self.y[i]).sum();
        let n = idx.len() as f64;
        let base_sse = total_sq - total_sum * total_sum / n;

        let mut best: Option<(usize, f64, f64)> = None; // (feat, thr, sse)
        let mut order: Vec<usize> = idx.to_vec();
        for (fi, f) in feats.into_iter().enumerate() {
            // stop at the sampled budget once any valid split was found
            if fi >= primary_k && best.is_some() {
                break;
            }
            order.sort_unstable_by(|&a, &b| {
                self.x[a][f].partial_cmp(&self.x[b][f]).unwrap()
            });
            let mut left_sum = 0.0;
            let mut left_sq = 0.0;
            for (pos, &i) in order.iter().enumerate().take(order.len() - 1) {
                left_sum += self.y[i];
                left_sq += self.y[i] * self.y[i];
                let nl = (pos + 1) as f64;
                let nr = n - nl;
                // can't split between equal feature values
                if self.x[i][f] == self.x[order[pos + 1]][f] {
                    continue;
                }
                if (pos + 1) < self.params.min_samples_leaf
                    || (order.len() - pos - 1) < self.params.min_samples_leaf
                {
                    continue;
                }
                let right_sum = total_sum - left_sum;
                let right_sq = total_sq - left_sq;
                let sse = (left_sq - left_sum * left_sum / nl)
                    + (right_sq - right_sum * right_sum / nr);
                if best.map(|(_, _, s)| sse < s).unwrap_or(sse < base_sse - 1e-12) {
                    let thr = 0.5 * (self.x[i][f] + self.x[order[pos + 1]][f]);
                    best = Some((f, thr, sse));
                }
            }
        }
        best.map(|(f, t, _)| (f, t))
    }

    fn build(&mut self, idx: &mut Vec<usize>, depth: usize) -> u32 {
        let node_id = self.nodes.len() as u32;
        let n = idx.len() as f64;
        let mean = idx.iter().map(|&i| self.y[i]).sum::<f64>() / n;
        self.nodes.push(Node {
            feature: usize::MAX,
            threshold: 0.0,
            left: 0,
            right: 0,
            value: mean,
        });

        if depth >= self.params.max_depth || idx.len() < 2 * self.params.min_samples_leaf {
            return node_id;
        }
        let Some((f, thr)) = self.best_split(idx) else {
            return node_id;
        };
        let (mut l, mut r): (Vec<usize>, Vec<usize>) =
            idx.iter().partition(|&&i| self.x[i][f] <= thr);
        if l.is_empty() || r.is_empty() {
            return node_id;
        }
        let left_id = self.build(&mut l, depth + 1);
        let right_id = self.build(&mut r, depth + 1);
        let node = &mut self.nodes[node_id as usize];
        node.feature = f;
        node.threshold = thr;
        node.left = left_id;
        node.right = right_id;
        node_id
    }
}

impl RegTree {
    pub fn fit(
        x: &[Vec<f64>],
        y: &[f64],
        idx: &[usize],
        params: TreeParams,
        rng: &mut Rng,
    ) -> RegTree {
        assert!(!idx.is_empty(), "empty training set");
        let mut b = Builder { x, y, params, rng, nodes: Vec::new() };
        let mut idx = idx.to_vec();
        b.build(&mut idx, 0);
        RegTree { nodes: b.nodes }
    }

    pub fn depth(&self) -> usize {
        fn d(nodes: &[Node], i: usize) -> usize {
            let n = nodes[i];
            if n.feature == usize::MAX {
                1
            } else {
                1 + d(nodes, n.left as usize).max(d(nodes, n.right as usize))
            }
        }
        d(&self.nodes, 0)
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }
}

impl RegTree {
    /// Iterative prediction: one array lookup per level.
    #[inline]
    pub fn predict(&self, x: &[f64]) -> f64 {
        let mut cur = 0usize;
        loop {
            let n = unsafe { self.nodes.get_unchecked(cur) };
            if n.feature == usize::MAX {
                return n.value;
            }
            cur = if x[n.feature] <= n.threshold {
                n.left as usize
            } else {
                n.right as usize
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Greedy-learnable two-level step function (NB: XOR would be the
    /// canonical greedy-CART failure — zero first-split gain — so we
    /// test on an additive target instead).
    fn step_data() -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..40 {
            let a = (i % 2) as f64;
            let b = ((i / 2) % 2) as f64;
            x.push(vec![a + 0.01 * (i as f64 / 40.0), b]);
            y.push(2.0 * a + b);
        }
        (x, y)
    }

    #[test]
    fn fits_two_level_step_exactly() {
        let (x, y) = step_data();
        let idx: Vec<usize> = (0..x.len()).collect();
        let mut rng = Rng::new(0);
        let t = RegTree::fit(&x, &y, &idx, TreeParams::default(), &mut rng);
        for (xi, yi) in x.iter().zip(y.iter()) {
            assert!((t.predict(xi) - yi).abs() < 1e-9);
        }
    }

    #[test]
    fn respects_max_depth() {
        let (x, y) = step_data();
        let idx: Vec<usize> = (0..x.len()).collect();
        let mut rng = Rng::new(0);
        let stump = RegTree::fit(
            &x,
            &y,
            &idx,
            TreeParams { max_depth: 1, ..Default::default() },
            &mut rng,
        );
        assert!(stump.depth() <= 2);
    }

    #[test]
    fn constant_target_single_leaf() {
        let x: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64]).collect();
        let y = vec![5.0; 10];
        let idx: Vec<usize> = (0..10).collect();
        let mut rng = Rng::new(0);
        let t = RegTree::fit(&x, &y, &idx, TreeParams::default(), &mut rng);
        assert_eq!(t.node_count(), 1);
        assert_eq!(t.predict(&[3.0]), 5.0);
    }

    #[test]
    fn min_samples_leaf_enforced() {
        let x: Vec<Vec<f64>> = (0..9).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..9).map(|i| i as f64).collect();
        let idx: Vec<usize> = (0..9).collect();
        let mut rng = Rng::new(0);
        let t = RegTree::fit(
            &x,
            &y,
            &idx,
            TreeParams { max_depth: 10, min_samples_leaf: 4, mtries: None },
            &mut rng,
        );
        // with min leaf 4 and 9 points, at most one split
        assert!(t.node_count() <= 3);
    }

    #[test]
    fn mtries_subsampling_still_learns() {
        let (x, y) = step_data();
        let idx: Vec<usize> = (0..x.len()).collect();
        let mut rng = Rng::new(1);
        let t = RegTree::fit(
            &x,
            &y,
            &idx,
            TreeParams { max_depth: 6, min_samples_leaf: 1, mtries: Some(1) },
            &mut rng,
        );
        let correct = x
            .iter()
            .zip(y.iter())
            .filter(|(xi, yi)| (t.predict(xi) - **yi).abs() < 0.5)
            .count();
        assert!(correct >= 30, "{correct}/40");
    }
}
