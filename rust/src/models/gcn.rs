//! GCN predictor over logical hierarchy graphs, backed by the AOT GCN
//! artifacts (paper §6 / Fig. 7): conv stack -> GlobalMeanPool ->
//! concat(global features) -> FC stack, trained with Adam + muAPE loss.
//!
//! Graph tensors are cached per *architecture* (the LHG does not depend
//! on backend knobs — paper §6), so a batch gathers cached rows rather
//! than re-normalizing adjacencies.

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::data::Dataset;
use crate::generators::Lhg;
use crate::runtime::{Batcher, Engine, ModelArch};
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

use super::ann::{glorot_init, TrainConfig};

/// Per-architecture GCN input tensors (flattened, f32).
pub struct GraphCache {
    pub n: usize,
    pub nf: usize,
    pub feats: Vec<Vec<f32>>, // [arch][N*NF]
    pub adjs: Vec<Vec<f32>>,  // [arch][N*N]
    pub masks: Vec<Vec<f32>>, // [arch][N]
}

impl GraphCache {
    pub fn build(lhgs: &[Lhg], max_nodes: usize) -> Result<GraphCache> {
        let nf = crate::generators::NODE_FEAT_DIM;
        let mut feats = Vec::with_capacity(lhgs.len());
        let mut adjs = Vec::with_capacity(lhgs.len());
        let mut masks = Vec::with_capacity(lhgs.len());
        for g in lhgs {
            let (f, a, m) = g.to_gcn_inputs(max_nodes)?;
            feats.push(f);
            adjs.push(a);
            masks.push(m);
        }
        Ok(GraphCache { n: max_nodes, nf, feats, adjs, masks })
    }
}

pub struct GcnModel {
    engine: Rc<Engine>,
    pub variant: String,
    pub cfg: TrainConfig,
    theta: Option<Tensor>,
    y_scale: f64,
    pub history: Vec<f64>,
    pub best_val_mu_ape: f64,
}

impl GcnModel {
    pub fn new(engine: Rc<Engine>, variant: &str, cfg: TrainConfig) -> Result<GcnModel> {
        let v = engine.manifest.variant(variant)?;
        anyhow::ensure!(matches!(v.arch, ModelArch::Gcn { .. }), "{variant} is not a GCN");
        Ok(GcnModel {
            engine,
            variant: variant.to_string(),
            cfg,
            theta: None,
            y_scale: 1.0,
            history: Vec::new(),
            best_val_mu_ape: f64::INFINITY,
        })
    }

    fn dims(&self) -> (usize, usize, usize, usize) {
        let m = &self.engine.manifest;
        (m.batch, m.feat, m.nodes, m.node_feat)
    }

    /// Assemble one [B]-batch of graph tensors for dataset rows `chunk`.
    #[allow(clippy::type_complexity)]
    fn pack_batch(
        &self,
        ds: &Dataset,
        cache: &GraphCache,
        chunk: &[usize],
        y_scaled: Option<&[f64]>,
    ) -> (Tensor, Tensor, Tensor, Tensor, Tensor, Tensor) {
        let (b, f, n, nf) = self.dims();
        let mut nodes = vec![0.0f32; b * n * nf];
        let mut adj = vec![0.0f32; b * n * n];
        let mut mask = vec![0.0f32; b * n];
        let mut gfeat = vec![0.0f32; b * f];
        let mut ys = vec![0.0f32; b];
        let mut ws = vec![0.0f32; b];
        for (slot, &row_idx) in chunk.iter().enumerate() {
            let row = &ds.rows[row_idx];
            let a = row.arch_idx;
            nodes[slot * n * nf..(slot + 1) * n * nf].copy_from_slice(&cache.feats[a]);
            adj[slot * n * n..(slot + 1) * n * n].copy_from_slice(&cache.adjs[a]);
            mask[slot * n..(slot + 1) * n].copy_from_slice(&cache.masks[a]);
            for (j, &v) in row.features.iter().enumerate().take(f) {
                gfeat[slot * f + j] = v as f32;
            }
            if let Some(y) = y_scaled {
                ys[slot] = y[row_idx] as f32;
            }
            ws[slot] = 1.0;
        }
        (
            Tensor::from_vec(&[b, n, nf], nodes).unwrap(),
            Tensor::from_vec(&[b, n, n], adj).unwrap(),
            Tensor::from_vec(&[b, n], mask).unwrap(),
            Tensor::from_vec(&[b, f], gfeat).unwrap(),
            Tensor::from_vec(&[b], ys).unwrap(),
            Tensor::from_vec(&[b], ws).unwrap(),
        )
    }

    /// Train on dataset rows `train_idx` for `target`; `val_idx` drives
    /// the LR schedule and early stopping.
    pub fn fit(
        &mut self,
        ds: &Dataset,
        cache: &GraphCache,
        train_idx: &[usize],
        val_idx: &[usize],
        targets: &[f64],
    ) -> Result<()> {
        anyhow::ensure!(!train_idx.is_empty(), "empty training set");
        let (b, ..) = self.dims();
        let v = self.engine.manifest.variant(&self.variant)?.clone();
        let step_file = v.entrypoint("train_step")?.file.clone();

        let mean_abs = train_idx
            .iter()
            .map(|&i| targets[i].abs())
            .sum::<f64>()
            / train_idx.len() as f64;
        self.y_scale = mean_abs.max(1e-12);
        let y_scaled: Vec<f64> = targets.iter().map(|t| t / self.y_scale).collect();
        let y_val: Vec<f64> = val_idx.iter().map(|&i| targets[i]).collect();

        let mut rng = Rng::new(self.cfg.seed ^ 0x6C9);
        let mut theta = glorot_init(&v, &mut rng);
        let p = v.param_total;
        let mut m = Tensor::zeros(&[p]);
        let mut vv = Tensor::zeros(&[p]);
        let mut t_step = 0f32;
        let mut lr = self.cfg.lr0;
        let mut best_theta = theta.clone();
        let mut best_val = f64::INFINITY;
        let (mut since_improve, mut since_decay) = (0usize, 0usize);
        self.history.clear();

        let mut order = train_idx.to_vec();
        for _epoch in 0..self.cfg.max_epochs {
            rng.shuffle(&mut order);
            for chunk in order.chunks(b) {
                let (nodes, adj, mask, gfeat, ys, ws) =
                    self.pack_batch(ds, cache, chunk, Some(&y_scaled));
                t_step += 1.0;
                let out = self.engine.run(
                    &step_file,
                    &[
                        theta,
                        m,
                        vv,
                        Tensor::scalar(t_step),
                        Tensor::scalar(lr),
                        nodes,
                        adj,
                        mask,
                        gfeat,
                        ys,
                        ws,
                    ],
                )?;
                let mut it = out.into_iter();
                theta = it.next().context("theta")?;
                m = it.next().context("m")?;
                vv = it.next().context("v")?;
            }

            self.theta = Some(theta.clone());
            let val_pred = self.predict_rows(ds, cache, val_idx)?;
            let val = crate::metrics::mape_stats(&y_val, &val_pred).mu_ape;
            self.history.push(val);
            if val < best_val - 1e-9 {
                best_val = val;
                best_theta = theta.clone();
                since_improve = 0;
                since_decay = 0;
            } else {
                since_improve += 1;
                since_decay += 1;
                if since_decay >= self.cfg.patience {
                    lr *= self.cfg.decay;
                    since_decay = 0;
                }
                if since_improve >= self.cfg.early_stop {
                    break;
                }
            }
        }
        self.theta = Some(best_theta);
        self.best_val_mu_ape = best_val;
        Ok(())
    }

    pub fn predict_rows(
        &self,
        ds: &Dataset,
        cache: &GraphCache,
        idx: &[usize],
    ) -> Result<Vec<f64>> {
        let theta = self.theta.as_ref().context("model not fitted")?;
        let (b, ..) = self.dims();
        let v = self.engine.manifest.variant(&self.variant)?;
        let file = &v.entrypoint("predict")?.file;
        let batcher = Batcher::new(b);
        let mut result = vec![0.0f32; idx.len()];
        for plan in batcher.plan(idx.len()) {
            let chunk: Vec<usize> = plan.rows.iter().map(|&r| idx[r]).collect();
            let (nodes, adj, mask, gfeat, _, _) = self.pack_batch(ds, cache, &chunk, None);
            let out =
                self.engine.run(file, &[theta.clone(), nodes, adj, mask, gfeat])?;
            batcher.unpack(&plan, out[0].data(), &mut result);
        }
        Ok(result.into_iter().map(|p| p as f64 * self.y_scale).collect())
    }

    /// Graph embeddings (Fig. 8 t-SNE input).
    pub fn embed_rows(
        &self,
        ds: &Dataset,
        cache: &GraphCache,
        idx: &[usize],
    ) -> Result<Vec<Vec<f64>>> {
        let theta = self.theta.as_ref().context("model not fitted")?;
        let (b, ..) = self.dims();
        let v = self.engine.manifest.variant(&self.variant)?;
        let ModelArch::Gcn { embed_dim, .. } = v.arch else { unreachable!() };
        let file = &v.entrypoint("embed")?.file;
        let batcher = Batcher::new(b);
        let mut result = vec![vec![0.0f64; embed_dim]; idx.len()];
        for plan in batcher.plan(idx.len()) {
            let chunk: Vec<usize> = plan.rows.iter().map(|&r| idx[r]).collect();
            let (nodes, adj, mask, _, _, _) = self.pack_batch(ds, cache, &chunk, None);
            let out = self.engine.run(file, &[theta.clone(), nodes, adj, mask])?;
            let emb = &out[0];
            for (slot, &src) in plan.rows.iter().enumerate() {
                for d in 0..embed_dim {
                    result[src][d] = emb.data()[slot * embed_dim + d] as f64;
                }
            }
        }
        Ok(result)
    }
}
