//! Stacked ensemble (paper §5.3): base learners (GBDT/RF/ANN survivors
//! of the hyperparameter search) combined by a linear-regression meta
//! learner fitted on held-out validation predictions.

use anyhow::Result;

use crate::util::json::Json;

use super::linear::Ridge;

/// A fitted base learner as the ensemble sees it: its validation and
/// test predictions (the ensemble never refits bases — it only learns
/// the combination, mirroring H2O's stacked ensemble over trained
/// models).
pub struct BasePredictions {
    pub name: String,
    pub val: Vec<f64>,
    pub test: Vec<f64>,
}

pub struct StackedEnsemble {
    pub base_names: Vec<String>,
    meta: Ridge,
}

impl StackedEnsemble {
    /// Fit the meta-learner on base predictions over the validation set.
    pub fn fit(bases: &[BasePredictions], y_val: &[f64]) -> Result<StackedEnsemble> {
        anyhow::ensure!(!bases.is_empty(), "no base learners");
        for b in bases {
            anyhow::ensure!(
                b.val.len() == y_val.len(),
                "{}: val size mismatch",
                b.name
            );
        }
        let x: Vec<Vec<f64>> = (0..y_val.len())
            .map(|i| bases.iter().map(|b| b.val[i]).collect())
            .collect();
        // Base predictions are highly correlated (they approximate the
        // same target), so a weak ridge yields huge +/- weight pairs that
        // amplify base disagreement on test data. Regularize relative to
        // the Gram scale.
        let scale: f64 = x
            .iter()
            .flat_map(|r| r.iter())
            .map(|v| v * v)
            .sum::<f64>()
            / x.len().max(1) as f64;
        let meta = Ridge::fit(&x, y_val, 0.05 * scale.max(1e-12));
        Ok(StackedEnsemble {
            base_names: bases.iter().map(|b| b.name.clone()).collect(),
            meta,
        })
    }

    /// Combine base test predictions.
    pub fn predict(&self, bases: &[BasePredictions]) -> Vec<f64> {
        let n = bases[0].test.len();
        (0..n)
            .map(|i| {
                let feats: Vec<f64> = bases.iter().map(|b| b.test[i]).collect();
                self.meta.predict_one(&feats)
            })
            .collect()
    }

    pub fn weights(&self) -> (&[f64], f64) {
        (&self.meta.weights, self.meta.intercept)
    }

    /// Model-store serialization (bit-exact prediction replay).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("base_names", Json::arr_str(&self.base_names)),
            ("meta", self.meta.to_json()),
        ])
    }

    /// Strict inverse of `to_json`: `None` on any defect (including a
    /// meta-learner arity that does not match the base count), so
    /// callers fall back to refitting.
    pub fn from_json(j: &Json) -> Option<StackedEnsemble> {
        let base_names = j
            .get("base_names")
            .as_arr()?
            .iter()
            .map(|v| v.as_str().map(String::from))
            .collect::<Option<Vec<_>>>()?;
        let meta = Ridge::from_json(j.get("meta"))?;
        if base_names.is_empty() || meta.weights.len() != base_names.len() {
            return None;
        }
        Some(StackedEnsemble { base_names, meta })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::rmse;
    use crate::util::rng::Rng;

    /// Two complementary noisy bases: the ensemble should beat both.
    #[test]
    fn ensemble_beats_each_base() {
        let mut rng = Rng::new(1);
        let n_val = 200;
        let n_test = 100;
        let y_val: Vec<f64> = (0..n_val).map(|_| rng.range(1.0, 10.0)).collect();
        let y_test: Vec<f64> = (0..n_test).map(|_| rng.range(1.0, 10.0)).collect();
        // base A: unbiased but noisy; base B: biased but precise
        let make = |y: &[f64], rng: &mut Rng| {
            let a: Vec<f64> = y.iter().map(|v| v + rng.normal()).collect();
            let b: Vec<f64> = y.iter().map(|v| 0.8 * v + 0.1 * rng.normal()).collect();
            (a, b)
        };
        let (av, bv) = make(&y_val, &mut rng);
        let (at, bt) = make(&y_test, &mut rng);
        let bases = vec![
            BasePredictions { name: "noisy".into(), val: av, test: at },
            BasePredictions { name: "biased".into(), val: bv, test: bt },
        ];
        let ens = StackedEnsemble::fit(&bases, &y_val).unwrap();
        let pred = ens.predict(&bases);
        let e_ens = rmse(&y_test, &pred);
        let e_a = rmse(&y_test, &bases[0].test);
        let e_b = rmse(&y_test, &bases[1].test);
        assert!(e_ens < e_a, "{e_ens} !< noisy {e_a}");
        assert!(e_ens < e_b, "{e_ens} !< biased {e_b}");
    }

    #[test]
    fn single_perfect_base_gets_weight_one() {
        let y: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let bases = vec![BasePredictions {
            name: "oracle".into(),
            val: y.clone(),
            test: y.clone(),
        }];
        let ens = StackedEnsemble::fit(&bases, &y).unwrap();
        let (w, b) = ens.weights();
        // ridge shrinks slightly below 1; intercept compensates
        assert!((w[0] - 1.0).abs() < 0.02, "{w:?}");
        assert!(b.abs() < 0.2, "{b}");
    }

    #[test]
    fn rejects_mismatched_sizes() {
        let bases = vec![BasePredictions {
            name: "bad".into(),
            val: vec![1.0; 3],
            test: vec![],
        }];
        assert!(StackedEnsemble::fit(&bases, &[1.0, 2.0]).is_err());
    }
}
