//! Hyperparameter tuning (paper §7.3, Table 2): random discrete search
//! with the two-stage max_depth narrowing protocol for GBDT/RF, model
//! selection by validation RMSE, and the rust mirror of Algorithm 2
//! (hidden-layer configuration) used to pick ANN variants.

use crate::metrics::rmse;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::gbdt::{Gbdt, GbdtParams};
use super::rf::{RandomForest, RfParams};

/// Algorithm 2 (paper): must agree exactly with python
/// `model.get_node_config` — test below pins the published examples.
pub fn get_node_config(node_count: usize, h_layer_count: usize) -> Vec<usize> {
    let (min_p, max_p) = (2usize, 7usize);
    let p = (usize::BITS - (node_count.max(1) - 1).leading_zeros()) as usize; // ceil(log2)
    let mut exp_max_p = ((h_layer_count + min_p + p) / 2).min(max_p);
    if exp_max_p <= p {
        exp_max_p = p + 1;
    }
    let incr_p = exp_max_p - p;
    let decr_p = (exp_max_p - min_p + 1).min(h_layer_count.saturating_sub(incr_p));
    let same_p = h_layer_count.saturating_sub(incr_p + decr_p);
    let mut layer = Vec::with_capacity(h_layer_count);
    let mut q = p;
    for _ in 0..incr_p {
        layer.push(1usize << q);
        q += 1;
    }
    for _ in 0..same_p {
        layer.push(1usize << q);
    }
    for _ in 0..decr_p {
        layer.push(1usize << q);
        q = q.saturating_sub(1);
    }
    layer
}

/// Search-budget knobs.
#[derive(Debug, Clone, Copy)]
pub struct SearchBudget {
    /// Random draws in stage 1 (broad) and stage 2 (narrowed).
    pub stage1: usize,
    pub stage2: usize,
    pub seed: u64,
}

impl Default for SearchBudget {
    fn default() -> Self {
        SearchBudget { stage1: 10, stage2: 6, seed: 23 }
    }
}

pub struct TunedGbdt {
    pub params: GbdtParams,
    pub model: Gbdt,
    pub val_rmse: f64,
}

impl TunedGbdt {
    /// Model-store serialization: the fitted model (which embeds its
    /// params) plus the search's validation RMSE, so a warm start
    /// replays the tuner's full outcome without a single evaluation.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", self.model.to_json()),
            ("val_rmse", self.val_rmse.into()),
        ])
    }

    pub fn from_json(j: &Json) -> Option<TunedGbdt> {
        let model = Gbdt::from_json(j.get("model"))?;
        let val_rmse = j.get("val_rmse").as_f64()?;
        Some(TunedGbdt { params: model.params, model, val_rmse })
    }
}

/// Two-stage random discrete search for GBDT (paper §7.3): stage 1 fixes
/// a large n_estimators and samples the rest; stage 2 narrows max_depth
/// to best +- 3 and re-samples.
pub fn tune_gbdt(
    x: &[Vec<f64>],
    y: &[f64],
    x_val: &[Vec<f64>],
    y_val: &[f64],
    budget: SearchBudget,
) -> TunedGbdt {
    let mut rng = Rng::new(budget.seed ^ 0x6BD7_5EA6);
    let mut eval = |params: GbdtParams, seed: u64| -> (f64, Gbdt) {
        let m = Gbdt::fit(x, y, params, seed);
        let e = rmse(y_val, &m.predict(x_val));
        (e, m)
    };

    // stage 1: n_estimators fixed high (paper: 300 for XGB)
    let mut best: Option<(f64, GbdtParams, Gbdt)> = None;
    for i in 0..budget.stage1 {
        let params = GbdtParams {
            n_estimators: 300,
            learning_rate: [0.03, 0.05, 0.08, 0.12][rng.below(4)],
            max_depth: rng.int_range(2, 20) as usize,
            min_samples_leaf: [1, 2, 4][rng.below(3)],
            subsample: [0.7, 0.85, 1.0][rng.below(3)],
        };
        let (e, m) = eval(params, i as u64);
        if best.as_ref().map(|(b, _, _)| e < *b).unwrap_or(true) {
            best = Some((e, params, m));
        }
    }
    let (_, stage1_params, _) = best.as_ref().unwrap();
    let center = stage1_params.max_depth as i64;

    // stage 2: narrow max_depth to best +- 3, tune n_estimators too
    for i in 0..budget.stage2 {
        let params = GbdtParams {
            n_estimators: [60, 120, 200, 300][rng.below(4)],
            learning_rate: [0.03, 0.05, 0.08, 0.12][rng.below(4)],
            max_depth: rng.int_range((center - 3).max(2), center + 3) as usize,
            min_samples_leaf: [1, 2, 4][rng.below(3)],
            subsample: [0.7, 0.85, 1.0][rng.below(3)],
        };
        let (e, m) = eval(params, 100 + i as u64);
        if best.as_ref().map(|(b, _, _)| e < *b).unwrap_or(true) {
            best = Some((e, params, m));
        }
    }
    let (val_rmse, params, model) = best.unwrap();
    TunedGbdt { params, model, val_rmse }
}

pub struct TunedRf {
    pub params: RfParams,
    pub model: RandomForest,
    pub val_rmse: f64,
}

impl TunedRf {
    /// Model-store serialization (the forest does not embed its
    /// params, so they ride alongside).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("params", self.params.to_json()),
            ("model", self.model.to_json()),
            ("val_rmse", self.val_rmse.into()),
        ])
    }

    pub fn from_json(j: &Json) -> Option<TunedRf> {
        Some(TunedRf {
            params: RfParams::from_json(j.get("params"))?,
            model: RandomForest::from_json(j.get("model"))?,
            val_rmse: j.get("val_rmse").as_f64()?,
        })
    }
}

pub fn tune_rf(
    x: &[Vec<f64>],
    y: &[f64],
    x_val: &[Vec<f64>],
    y_val: &[f64],
    budget: SearchBudget,
) -> TunedRf {
    let n_feat = x[0].len();
    let mut rng = Rng::new(budget.seed ^ 0x2F);
    let mut best: Option<(f64, RfParams, RandomForest)> = None;
    let mut try_params = |params: RfParams, seed: u64, best: &mut Option<(f64, RfParams, RandomForest)>| {
        let m = RandomForest::fit(x, y, params, seed);
        let e = rmse(y_val, &m.predict(x_val));
        if best.as_ref().map(|(b, _, _)| e < *b).unwrap_or(true) {
            *best = Some((e, params, m));
        }
    };
    // stage 1: trees fixed high (paper: 500), sample mtries/depth
    for i in 0..budget.stage1 {
        let params = RfParams {
            n_estimators: 300,
            max_depth: rng.int_range(5, 40) as usize,
            min_samples_leaf: [1, 2][rng.below(2)],
            mtries: Some(rng.int_range(1, n_feat as i64) as usize),
        };
        try_params(params, i as u64, &mut best);
    }
    let (_, s1, _) = best.as_ref().unwrap();
    let (center, mtries) = (s1.max_depth as i64, s1.mtries);
    // stage 2: depth narrowed, mtries retained (paper protocol)
    for i in 0..budget.stage2 {
        let params = RfParams {
            n_estimators: [100, 200, 300][rng.below(3)],
            max_depth: rng.int_range((center - 3).max(3), center + 3) as usize,
            min_samples_leaf: [1, 2][rng.below(2)],
            mtries,
        };
        try_params(params, 100 + i as u64, &mut best);
    }
    let (val_rmse, params, model) = best.unwrap();
    TunedRf { params, model, val_rmse }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algorithm2_matches_python_reference() {
        // pinned against python model.get_node_config (test_model.py)
        assert_eq!(get_node_config(32, 4), vec![32, 64, 32, 16]);
        assert_eq!(get_node_config(16, 3), vec![16, 32, 16]);
        assert_eq!(get_node_config(64, 5), vec![64, 128, 64, 32, 16]);
    }

    #[test]
    fn algorithm2_length_always_matches() {
        for nodes in [8, 16, 32, 64] {
            for layers in 3..=9 {
                assert_eq!(get_node_config(nodes, layers).len(), layers);
            }
        }
    }

    fn toy() -> (Vec<Vec<f64>>, Vec<f64>, Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(3);
        let gen = |n: usize, rng: &mut Rng| {
            let x: Vec<Vec<f64>> =
                (0..n).map(|_| (0..4).map(|_| rng.f64()).collect()).collect();
            let y: Vec<f64> =
                x.iter().map(|v| 5.0 * v[0] * v[1] + v[2]).collect();
            (x, y)
        };
        let (x, y) = gen(150, &mut rng);
        let (xv, yv) = gen(60, &mut rng);
        (x, y, xv, yv)
    }

    #[test]
    fn tuned_gbdt_beats_default_or_close() {
        let (x, y, xv, yv) = toy();
        let budget = SearchBudget { stage1: 4, stage2: 3, seed: 1 };
        let tuned = tune_gbdt(&x, &y, &xv, &yv, budget);
        let default = Gbdt::fit(&x, &y, GbdtParams::default(), 0);
        let e_def = rmse(&yv, &default.predict(&xv));
        assert!(tuned.val_rmse <= e_def * 1.02, "{} vs {}", tuned.val_rmse, e_def);
    }

    #[test]
    fn tuned_rf_is_sane() {
        let (x, y, xv, yv) = toy();
        let budget = SearchBudget { stage1: 3, stage2: 2, seed: 1 };
        let tuned = tune_rf(&x, &y, &xv, &yv, budget);
        let spread = {
            let mean = yv.iter().sum::<f64>() / yv.len() as f64;
            (yv.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / yv.len() as f64)
                .sqrt()
        };
        assert!(tuned.val_rmse < spread, "{} vs {}", tuned.val_rmse, spread);
    }
}
