//! Flattened structure-of-arrays (SoA) forest inference — the batch
//! hot path under `Gbdt`, `GbdtClassifier`, and `RandomForest`.
//!
//! `RegTree` keeps one `Vec<Node>` per tree: batch prediction over a
//! forest pointer-chases a fresh allocation per tree per row, which
//! profiles as the innermost hot loop of the DSE once oracle traffic
//! is cached and coalesced (PRs 1-5). `FlatForest` repacks every tree
//! of a fitted forest back-to-back into contiguous per-field slabs
//! (`feature[]`, `threshold[]`, `left[]`, `right[]`, `value[]`) with
//! absolute child indices, then walks them tree-major / row-minor: the
//! tree being traversed stays hot in cache across the whole batch and
//! the walk itself is branch-light (one predicated child select per
//! level, no call per tree).
//!
//! ## Bit-identity contract
//!
//! Flat predictions are **bit-identical** to the recursive reference
//! walkers (`RegTree::predict` per tree, summed in tree order):
//!
//! * each row's accumulator starts at 0.0 and adds leaf values in tree
//!   order — exactly the fold `trees.iter().map(|t| t.predict(x)).sum()`
//!   performs, so f64 rounding is reproduced addition-for-addition;
//! * the split test is the same `x[feature] <= threshold` expression,
//!   so NaN features route right and ±Inf/-0.0 compare identically;
//! * row-chunked parallelism only partitions rows (never reorders a
//!   row's additions), so worker count cannot change a single bit.
//!
//! That contract is what lets every mega-batch path (`SurrogateBundle`,
//! `EvalService::predict_batch`, the `EvalRouter`) switch to the flat
//! layout without touching the repo's determinism spine (fixed seed ⇒
//! byte-identical CSVs, reports, Pareto fronts). `tests/flat_tree.rs`
//! enforces it differentially, NaN/±Inf/-0.0 features included.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::util::pool::par_map;

use super::tree::RegTree;

/// Leaf sentinel in the packed `feature` slab.
const LEAF: u32 = u32::MAX;

/// Rows per parallel chunk. Chunking partitions the batch across
/// workers without reordering any row's per-tree additions.
const CHUNK: usize = 128;

/// A forest of regression trees packed into contiguous SoA slabs.
/// Built once at fit/deserialization time; read-only afterwards.
#[derive(Debug)]
pub struct FlatForest {
    /// Split feature per node (`LEAF` = leaf).
    feature: Vec<u32>,
    threshold: Vec<f64>,
    /// Absolute child indices into the packed slab (per-tree base
    /// already applied).
    left: Vec<u32>,
    right: Vec<u32>,
    /// Leaf prediction (internal nodes keep their training mean, as in
    /// `RegTree`; the walk never reads it there).
    value: Vec<f64>,
    /// Tree `t` occupies nodes `roots[t]..roots[t+1]`; `len = trees+1`.
    roots: Vec<u32>,
    /// Batch-entry instrumentation: `sum_batch` invocations and rows
    /// scored. Per-instance (not global) so concurrent tests can pin
    /// call counts without cross-talk; one relaxed fetch_add per batch,
    /// nothing per row.
    batches: AtomicUsize,
    rows: AtomicUsize,
}

impl Clone for FlatForest {
    fn clone(&self) -> FlatForest {
        FlatForest {
            feature: self.feature.clone(),
            threshold: self.threshold.clone(),
            left: self.left.clone(),
            right: self.right.clone(),
            value: self.value.clone(),
            roots: self.roots.clone(),
            batches: AtomicUsize::new(self.batches.load(Ordering::Relaxed)),
            rows: AtomicUsize::new(self.rows.load(Ordering::Relaxed)),
        }
    }
}

impl FlatForest {
    /// Pack validated trees (fit output or `RegTree::from_json`, both
    /// of which enforce forward child edges) into one slab set.
    pub fn from_trees(trees: &[RegTree]) -> FlatForest {
        let total: usize = trees.iter().map(|t| t.node_count()).sum();
        assert!(
            total < LEAF as usize,
            "forest too large for u32 node indices ({total} nodes)"
        );
        let mut f = FlatForest {
            feature: Vec::with_capacity(total),
            threshold: Vec::with_capacity(total),
            left: Vec::with_capacity(total),
            right: Vec::with_capacity(total),
            value: Vec::with_capacity(total),
            roots: Vec::with_capacity(trees.len() + 1),
            batches: AtomicUsize::new(0),
            rows: AtomicUsize::new(0),
        };
        f.roots.push(0);
        for tree in trees {
            let base = f.feature.len() as u32;
            for n in tree.nodes() {
                f.feature.push(if n.feature == usize::MAX {
                    LEAF
                } else {
                    n.feature as u32
                });
                f.threshold.push(n.threshold);
                // leaves carry left/right 0; base+0 points at this
                // tree's own root and is never followed
                f.left.push(base + n.left);
                f.right.push(base + n.right);
                f.value.push(n.value);
            }
            f.roots.push(f.feature.len() as u32);
        }
        f
    }

    pub fn n_trees(&self) -> usize {
        self.roots.len() - 1
    }

    pub fn n_nodes(&self) -> usize {
        self.feature.len()
    }

    /// (batch invocations, rows scored) through `sum_batch` so far —
    /// the call-count regression tests' probe.
    pub fn stats(&self) -> (usize, usize) {
        (self.batches.load(Ordering::Relaxed), self.rows.load(Ordering::Relaxed))
    }

    /// Walk one tree for one row. Same comparison expression as
    /// `RegTree::predict` (NaN routes right); compiles to a predicated
    /// child select per level.
    #[inline]
    fn walk(&self, root: u32, x: &[f64]) -> f64 {
        let mut cur = root as usize;
        loop {
            // SAFETY: `from_trees` packs only validated trees whose
            // child edges stay inside their own node range; adding the
            // per-tree base keeps every index < n_nodes.
            let f = unsafe { *self.feature.get_unchecked(cur) };
            if f == LEAF {
                return unsafe { *self.value.get_unchecked(cur) };
            }
            // bounds-checked row access, exactly like the reference
            // walker (a short feature row must fail identically)
            let go_left = x[f as usize] <= unsafe { *self.threshold.get_unchecked(cur) };
            cur = if go_left {
                unsafe { *self.left.get_unchecked(cur) }
            } else {
                unsafe { *self.right.get_unchecked(cur) }
            } as usize;
        }
    }

    /// Tree-major accumulation over a row range: for each tree, score
    /// every row before moving on, keeping the tree's slab segment hot.
    /// Per row this adds leaf values in tree order from 0.0 — the exact
    /// fold of the recursive reference, bit for bit.
    fn sum_range(&self, xs: &[Vec<f64>], lo: usize, hi: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), hi - lo);
        for t in 0..self.n_trees() {
            let root = self.roots[t];
            for (acc, x) in out.iter_mut().zip(&xs[lo..hi]) {
                *acc += self.walk(root, x);
            }
        }
    }

    /// Per-row tree-sums for a batch: the single batch entry point all
    /// forest models route through. `workers > 1` chunks rows across
    /// the scoped pool; chunking never reorders a row's additions, so
    /// the output is worker-count-invariant down to the bit.
    pub fn sum_batch(&self, xs: &[Vec<f64>], workers: usize) -> Vec<f64> {
        let n = xs.len();
        if n == 0 {
            return Vec::new();
        }
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(n, Ordering::Relaxed);
        let workers = workers.max(1);
        if workers == 1 || n <= CHUNK {
            let mut out = vec![0.0; n];
            self.sum_range(xs, 0, n, &mut out);
            return out;
        }
        let chunks = (n + CHUNK - 1) / CHUNK;
        let pieces = par_map(chunks, workers, |c| {
            let lo = c * CHUNK;
            let hi = ((c + 1) * CHUNK).min(n);
            let mut out = vec![0.0; hi - lo];
            self.sum_range(xs, lo, hi, &mut out);
            out
        });
        pieces.concat()
    }
}

#[cfg(test)]
mod tests {
    use super::super::tree::TreeParams;
    use super::*;
    use crate::util::rng::Rng;

    fn forest(n_trees: usize, seed: u64) -> (Vec<RegTree>, Vec<Vec<f64>>) {
        let mut rng = Rng::new(seed);
        let x: Vec<Vec<f64>> =
            (0..80).map(|_| (0..6).map(|_| rng.f64()).collect()).collect();
        let y: Vec<f64> = x.iter().map(|v| v[0] * 4.0 - v[1] + v[2] * v[3]).collect();
        let idx: Vec<usize> = (0..x.len()).collect();
        let trees = (0..n_trees)
            .map(|_| RegTree::fit(&x, &y, &idx, TreeParams::default(), &mut rng))
            .collect();
        (trees, x)
    }

    #[test]
    fn packs_every_node_and_tree() {
        let (trees, _) = forest(7, 1);
        let flat = FlatForest::from_trees(&trees);
        assert_eq!(flat.n_trees(), 7);
        assert_eq!(
            flat.n_nodes(),
            trees.iter().map(|t| t.node_count()).sum::<usize>()
        );
    }

    #[test]
    fn matches_reference_sum_bitwise() {
        let (trees, x) = forest(9, 2);
        let flat = FlatForest::from_trees(&trees);
        let sums = flat.sum_batch(&x, 1);
        for (row, s) in x.iter().zip(&sums) {
            let reference: f64 = trees.iter().map(|t| t.predict(row)).sum();
            assert_eq!(s.to_bits(), reference.to_bits());
        }
    }

    #[test]
    fn worker_count_never_changes_bits() {
        let (trees, x) = forest(5, 3);
        // tile rows well past CHUNK so the parallel path actually chunks
        let xs: Vec<Vec<f64>> =
            (0..4 * CHUNK + 17).map(|i| x[i % x.len()].clone()).collect();
        let flat = FlatForest::from_trees(&trees);
        let serial = flat.sum_batch(&xs, 1);
        for workers in [2, 3, 8] {
            let par = flat.sum_batch(&xs, workers);
            assert_eq!(serial.len(), par.len());
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn empty_forest_and_empty_batch() {
        let (trees, x) = forest(3, 4);
        let flat = FlatForest::from_trees(&trees);
        assert!(flat.sum_batch(&[], 4).is_empty());
        let none = FlatForest::from_trees(&[]);
        assert_eq!(none.n_trees(), 0);
        assert_eq!(none.sum_batch(&x, 1), vec![0.0; x.len()]);
    }

    #[test]
    fn counts_batches_and_rows() {
        let (trees, x) = forest(2, 5);
        let flat = FlatForest::from_trees(&trees);
        assert_eq!(flat.stats(), (0, 0));
        flat.sum_batch(&x, 1);
        flat.sum_batch(&x[..10], 4);
        assert_eq!(flat.stats(), (2, x.len() + 10));
        // empty batches are not counted
        flat.sum_batch(&[], 1);
        assert_eq!(flat.stats(), (2, x.len() + 10));
    }
}
