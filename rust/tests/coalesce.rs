//! Deterministic coalescing tests (ISSUE 5): the barrier hooks in
//! `coordinator::coalesce::hook` (mirroring `store::fault`) force
//! exact interleavings — "N waiters queued before the leader
//! finishes", "N requests queued before the router drains" — without
//! a single sleep, so these assertions hold on any machine and any
//! scheduler.
//!
//! The hooks are process-global one-shots, so every test here
//! serializes on one mutex (a test that creates flights or routers
//! while another test's barrier is armed would consume it).

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use fso::backend::{BackendConfig, Enablement};
use fso::coordinator::coalesce::{hook, Joined, SingleFlight};
use fso::coordinator::dse_driver::{axiline_svm_problem, DseDriver, SurrogateBundle};
use fso::coordinator::{
    datagen, CacheStore, DatagenConfig, EvalRouter, EvalService, ModelMenu,
    SurrogatePoint, TrainOptions, Trainer,
};
use fso::data::Metric;
use fso::dse::MotpeConfig;
use fso::generators::{ArchConfig, Platform};
use fso::models::SearchBudget;

/// Serializes every test in this binary (see module docs).
static HOOKS: Mutex<()> = Mutex::new(());

fn lock_hooks() -> std::sync::MutexGuard<'static, ()> {
    let guard = HOOKS.lock().unwrap_or_else(|p| p.into_inner());
    // a previous test that failed between arm and disarm must not
    // leak its barrier into this one
    hook::disarm();
    guard
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("fso-coalesce-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn mid_arch(p: Platform) -> ArchConfig {
    ArchConfig::new(
        p,
        p.param_space().iter().map(|s| s.kind.from_unit(0.5)).collect(),
    )
}

fn small_cfg() -> DatagenConfig {
    DatagenConfig {
        n_arch: 6,
        n_backend_train: 10,
        n_backend_test: 4,
        ..DatagenConfig::small(Platform::Axiline, Enablement::Gf12)
    }
}

#[test]
fn n_waiters_queued_before_leader_finishes_share_one_computation() {
    let _g = lock_hooks();
    let sf: SingleFlight<u64> = SingleFlight::new();
    let runs = AtomicUsize::new(0);
    const WAITERS: usize = 4;
    hook::arm_leader_barrier(WAITERS);
    let outcomes: Vec<bool> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..WAITERS + 1)
            .map(|_| {
                let sf = &sf;
                let runs = &runs;
                scope.spawn(move || {
                    match sf
                        .run(0xC0A1, || {
                            runs.fetch_add(1, Ordering::SeqCst);
                            Ok(42u64)
                        })
                        .unwrap()
                    {
                        Joined::Led(v) => {
                            assert_eq!(v, 42);
                            true
                        }
                        Joined::Coalesced(v) => {
                            assert_eq!(v, 42);
                            false
                        }
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    hook::disarm();
    assert_eq!(runs.load(Ordering::SeqCst), 1, "exactly one caller computes");
    assert_eq!(outcomes.iter().filter(|&&led| led).count(), 1);
    assert_eq!(outcomes.iter().filter(|&&led| !led).count(), WAITERS);
    assert_eq!(sf.inflight_peak(), 1, "one key, one flight in the air");
}

#[test]
fn leader_panic_propagates_to_every_waiter_and_table_stays_clean() {
    let _g = lock_hooks();
    // silence the default hook while the expected panics fire
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let sf: SingleFlight<u64> = SingleFlight::new();
    const WAITERS: usize = 3;
    hook::arm_leader_barrier(WAITERS);
    let outcomes: Vec<Result<Result<Joined<u64>, String>, String>> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..WAITERS + 1)
                .map(|_| {
                    let sf = &sf;
                    scope.spawn(move || {
                        sf.run(7, || -> anyhow::Result<u64> {
                            panic!("oracle exploded mid-flight")
                        })
                        .map_err(|e| format!("{e:#}"))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().map_err(|payload| {
                        payload
                            .downcast_ref::<String>()
                            .cloned()
                            .or_else(|| {
                                payload.downcast_ref::<&str>().map(|s| s.to_string())
                            })
                            .unwrap_or_default()
                    })
                })
                .collect()
        });
    std::panic::set_hook(prev);
    hook::disarm();
    assert_eq!(outcomes.len(), WAITERS + 1);
    for o in &outcomes {
        let msg = o.as_ref().expect_err("every caller must observe the panic");
        assert!(
            msg.contains("oracle exploded mid-flight"),
            "panic payload lost: {msg:?}"
        );
    }
    // the key is released: a later call recomputes instead of hanging
    let v = match sf.run(7, || Ok(9u64)).unwrap() {
        Joined::Led(v) | Joined::Coalesced(v) => v,
    };
    assert_eq!(v, 9);
}

#[test]
fn waiters_receive_the_leaders_full_error_context_chain() {
    let _g = lock_hooks();
    // ISSUE 10 satellite: a leader failure used to cross the flight as
    // one flattened string, so waiters lost the anyhow context chain
    // (`"loading shard 3"` and friends). Pin the full waiter-side
    // rendering: every layer of the leader's chain, in order, behind
    // the `coalesced leader failed` marker.
    let sf: SingleFlight<u64> = SingleFlight::new();
    const WAITERS: usize = 2;
    hook::arm_leader_barrier(WAITERS);
    let msgs: Vec<(bool, String)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..WAITERS + 1)
            .map(|_| {
                let sf = &sf;
                scope.spawn(move || {
                    let err = sf
                        .run(0xE44, || -> anyhow::Result<u64> {
                            Err(anyhow::anyhow!("disk exploded")
                                .context("loading shard 3")
                                .context("oracle cache read"))
                        })
                        .expect_err("every caller must observe the failure");
                    let msg = format!("{err:#}");
                    (msg.starts_with("coalesced leader failed"), msg)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    hook::disarm();
    let (coalesced, led): (Vec<_>, Vec<_>) = msgs.into_iter().partition(|(c, _)| *c);
    assert_eq!(led.len(), 1, "exactly one caller led the failing flight");
    assert_eq!(
        led[0].1, "oracle cache read: loading shard 3: disk exploded",
        "the leader keeps its original error"
    );
    assert_eq!(coalesced.len(), WAITERS);
    for (_, msg) in &coalesced {
        assert_eq!(
            msg,
            "coalesced leader failed: oracle cache read: loading shard 3: disk exploded",
            "waiter lost part of the leader's context chain"
        );
    }
}

#[test]
fn coalesced_evaluate_runs_oracle_once_and_writes_store_once() {
    let _g = lock_hooks();
    let dir = tmp_dir("evaluate");
    let store = Arc::new(CacheStore::open(&dir).unwrap());
    let svc = EvalService::new(Enablement::Gf12, 7)
        .with_coalescing(true)
        .with_cache_store(Arc::clone(&store));
    let arch = mid_arch(Platform::Axiline);
    let bcfg = BackendConfig::new(0.8, 0.5);
    const WAITERS: usize = 3;
    hook::arm_leader_barrier(WAITERS);
    let evals: Vec<_> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..WAITERS + 1)
            .map(|_| {
                let svc = &svc;
                let arch = &arch;
                scope.spawn(move || svc.evaluate(arch, bcfg, None).unwrap())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    hook::disarm();
    // every waiter received the leader's bit-identical result
    let reference = EvalService::new(Enablement::Gf12, 7)
        .evaluate(&arch, bcfg, None)
        .unwrap();
    for e in &evals {
        assert_eq!(e.flow.backend, reference.flow.backend);
        assert_eq!(e.flow.synth, reference.flow.synth);
        assert_eq!(e.system, reference.system);
    }
    let s = svc.stats();
    assert_eq!(s.oracle_runs, 1, "single-flight must run the oracle once: {s}");
    assert_eq!(s.flow_runs, 1, "{s}");
    assert_eq!(s.oracle_misses, 1, "{s}");
    assert_eq!(s.coalesced_hits, WAITERS, "{s}");
    assert_eq!(s.oracle_hits, WAITERS, "waits count as hits: {s}");
    assert_eq!(s.inflight_peak, 1, "{s}");
    // the store was fed exactly once per key: one flow + one eval record
    assert_eq!(store.stats().pending, 2, "store written once per key");
    assert!(svc.flush_cache().unwrap() > 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn router_coalesces_cross_client_single_rows_into_one_mega_batch() {
    let _g = lock_hooks();
    let cfg = DatagenConfig {
        n_arch: 4,
        n_backend_train: 6,
        n_backend_test: 2,
        ..DatagenConfig::small(Platform::Axiline, Enablement::Gf12)
    };
    let g = datagen::generate(&cfg).unwrap();
    let bundle = SurrogateBundle::fit(&g.dataset, &g.backend_split, 7).unwrap();
    let service =
        Arc::new(EvalService::new(Enablement::Gf12, cfg.seed).with_surrogate(bundle));
    let feats: Vec<Vec<f64>> =
        g.dataset.rows.iter().take(6).map(|r| r.features_vec()).collect();
    let reference = service.predict_batch(&feats).unwrap();

    let router = EvalRouter::start(Arc::clone(&service));
    const CLIENTS: usize = 6;
    hook::arm_router_barrier(CLIENTS);
    let outs: Vec<SurrogatePoint> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                let client = router.client();
                let row = feats[c].clone();
                scope.spawn(move || client.predict(vec![row]).unwrap().pop().unwrap())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    hook::disarm();
    for (c, sp) in outs.iter().enumerate() {
        assert_eq!(*sp, reference[c], "row {c}: routed batching changed a value");
    }
    let s = service.stats();
    assert_eq!(s.router_requests, CLIENTS, "{s}");
    assert_eq!(s.router_rows, CLIENTS, "{s}");
    assert_eq!(
        s.router_batches, 1,
        "barrier forced every client into one mega-batch: {s}"
    );
    assert!((s.router_occupancy() - CLIENTS as f64).abs() < 1e-9);
    drop(router);
}

#[test]
fn router_shutdown_replies_to_inflight_requests_instead_of_hanging() {
    let _g = lock_hooks();
    let cfg = DatagenConfig {
        n_arch: 4,
        n_backend_train: 6,
        n_backend_test: 2,
        ..DatagenConfig::small(Platform::Axiline, Enablement::Gf12)
    };
    let g = datagen::generate(&cfg).unwrap();
    let bundle = SurrogateBundle::fit(&g.dataset, &g.backend_split, 7).unwrap();
    let service =
        Arc::new(EvalService::new(Enablement::Gf12, cfg.seed).with_surrogate(bundle));
    let feats: Vec<Vec<f64>> =
        g.dataset.rows.iter().take(2).map(|r| r.features_vec()).collect();

    // the router's drain is held open waiting for 3 requests, but only
    // 2 ever arrive before the shutdown: both callers must receive a
    // reply (a result or a disconnect error), never hang. If anything
    // hangs, the scope join below never returns and the test times out.
    let router = EvalRouter::start(Arc::clone(&service));
    hook::arm_router_barrier(3);
    let (r1, r2) = std::thread::scope(|scope| {
        let h1 = {
            let client = router.client();
            let row = feats[0].clone();
            scope.spawn(move || client.predict(vec![row]))
        };
        let h2 = {
            let client = router.client();
            let row = feats[1].clone();
            scope.spawn(move || client.predict(vec![row]))
        };
        drop(router); // sends Shutdown and joins the serve thread
        (h1.join().unwrap(), h2.join().unwrap())
    });
    hook::disarm();
    for r in [r1, r2] {
        match r {
            Ok(points) => assert_eq!(points.len(), 1),
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(msg.contains("router"), "unexpected error: {msg}");
            }
        }
    }
}

#[test]
fn pipelined_dse_matches_strict_alternation_byte_for_byte() {
    let _g = lock_hooks();
    let g = datagen::generate(&small_cfg()).unwrap();
    let mut runtimes: Vec<f64> = g.dataset.rows.iter().map(|r| r.runtime_s).collect();
    runtimes.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let problem = axiline_svm_problem(
        g.dataset.rows.iter().map(|r| r.power_w).fold(0.0, f64::max) * 2.0,
        runtimes[runtimes.len() * 3 / 4],
    );
    let mk_driver = || {
        let bundle = SurrogateBundle::fit(&g.dataset, &g.backend_split, 1).unwrap();
        DseDriver {
            service: EvalService::new(Enablement::Gf12, 2023)
                .with_surrogate(bundle)
                .with_workers(2),
        }
    };
    let motpe_cfg = || MotpeConfig { n_startup: 16, seed: 5, ..Default::default() };
    let strict = mk_driver().run_batched(&problem, 60, 2, motpe_cfg(), 12).unwrap();
    assert!(!strict.best.is_empty(), "Eq. 3 must select winners to compare");
    for inflight in [1usize, 3] {
        let piped = mk_driver()
            .run_pipelined(&problem, 60, 2, motpe_cfg(), 12, inflight)
            .unwrap();
        assert_eq!(strict.points, piped.points, "trajectory diverged (x{inflight})");
        assert_eq!(strict.best, piped.best, "Eq. 3 winners diverged (x{inflight})");
        assert_eq!(strict.ground_truth_errors, piped.ground_truth_errors);
        assert_eq!(
            strict.pareto_front(),
            piped.pareto_front(),
            "Pareto front diverged (x{inflight})"
        );
    }
}

#[test]
fn trainer_fit_memo_shares_identical_fits_without_changing_reports() {
    let _g = lock_hooks();
    let g = datagen::generate(&DatagenConfig {
        n_arch: 8,
        n_backend_train: 12,
        n_backend_test: 4,
        ..DatagenConfig::small(Platform::Axiline, Enablement::Gf12)
    })
    .unwrap();
    let opts = TrainOptions {
        menu: ModelMenu::trees_only(),
        search: SearchBudget { stage1: 3, stage2: 2, seed: 1 },
        seed: 7,
        ..Default::default()
    };

    // plain trainer: the metric-independent ROI classifier refits for
    // every metric; the memoized trainer fits it once and replays it
    let plain = Trainer::new(None);
    let memo = Trainer::new(None).with_fit_coalescing();
    let p_power = plain.run(&g.dataset, &g.backend_split, Metric::Power, &opts).unwrap();
    let p_area = plain.run(&g.dataset, &g.backend_split, Metric::Area, &opts).unwrap();
    let m_power = memo.run(&g.dataset, &g.backend_split, Metric::Power, &opts).unwrap();
    let m_area = memo.run(&g.dataset, &g.backend_split, Metric::Area, &opts).unwrap();

    assert_eq!(p_power.model_cache.cached, 0, "no store, no memo: all fresh");
    assert_eq!(p_area.model_cache.cached, 0);
    assert_eq!(m_power.model_cache.cached, 0, "first run fits everything");
    assert!(
        m_area.model_cache.cached >= 1,
        "second metric must replay the memoized ROI classifier: {:?}",
        m_area.model_cache
    );
    assert!(m_area.model_cache.refits < p_area.model_cache.refits);

    // the memo never changes a number
    assert_eq!(p_power.roi, m_power.roi);
    assert_eq!(p_power.models, m_power.models);
    assert_eq!(p_area.roi, m_area.roi);
    assert_eq!(p_area.models, m_area.models);

    // a full rerun of an already-seen metric is 100% memoized
    let rerun = memo.run(&g.dataset, &g.backend_split, Metric::Power, &opts).unwrap();
    assert_eq!(rerun.model_cache.refits, 0, "{:?}", rerun.model_cache);
    assert_eq!(rerun.model_cache.tuning_evals, 0);
    assert_eq!(rerun.models, m_power.models);
}
