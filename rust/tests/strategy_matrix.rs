//! Strategy zoo x workload matrix (ISSUE 8): every cell of the
//! (strategy, workload, enablement) grid must honor the determinism
//! contract — a fixed seed yields byte-identical trajectories, Eq.-3
//! winners, and Pareto fronts across the strict and pipelined cadences,
//! repeat runs, and warm `--cache-dir` starts — and MOTPE must beat
//! random search on the same budget through the full `DseDriver` path.

use std::path::PathBuf;
use std::sync::Arc;

use fso::backend::Enablement;
use fso::coordinator::dse_driver::{
    axiline_svm_problem, vta_backend_problem, DseDriver, DseOutcome, SurrogateBundle,
};
use fso::coordinator::{datagen, CacheStore, DatagenConfig, DseProblem, EvalService, GeneratedData};
use fso::data::Metric;
use fso::dse::{MotpeConfig, StrategyKind};
use fso::generators::{ArchConfig, Platform};

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("fso-strategy-matrix-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

// sizes mirror tests/e2e_small.rs / warm_start.rs, known to yield a
// non-empty feasible front on Axiline
fn gen_data(platform: Platform, enablement: Enablement, workload: Option<&str>) -> GeneratedData {
    datagen::generate(&DatagenConfig {
        n_arch: 6,
        n_backend_train: 10,
        n_backend_test: 4,
        workload: workload.map(String::from),
        ..DatagenConfig::small(platform, enablement)
    })
    .unwrap()
}

/// The paper's problem shape for the dataset's platform, with the
/// cell's workload override routed into the oracle simulators.
fn problem_for(g: &GeneratedData, workload: Option<&str>) -> DseProblem {
    let mut runtimes: Vec<f64> = g.dataset.rows.iter().map(|r| r.runtime_s).collect();
    runtimes.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let r_max = runtimes[runtimes.len() * 3 / 4];
    let p_max = g.dataset.rows.iter().map(|r| r.power_w).fold(0.0, f64::max) * 2.0;
    match g.dataset.platform {
        Platform::Axiline => axiline_svm_problem(p_max, r_max),
        Platform::Vta => {
            let base = ArchConfig::new(
                Platform::Vta,
                Platform::Vta.param_space().iter().map(|s| s.kind.from_unit(0.5)).collect(),
            );
            let mut problem = vta_backend_problem(base, p_max, r_max);
            problem.workload = workload.map(|n| fso::workloads::lookup(n).unwrap());
            problem
        }
        p => panic!("no DSE problem shape for {p}"),
    }
}

fn mk_driver(g: &GeneratedData) -> DseDriver {
    let bundle = SurrogateBundle::fit(&g.dataset, &g.backend_split, 1).unwrap();
    DseDriver::new(g.dataset.enablement, bundle, 2023).with_workers(2)
}

fn strategy_cfg() -> MotpeConfig {
    MotpeConfig { n_startup: 16, seed: 5, ..Default::default() }
}

fn run_strict(
    g: &GeneratedData,
    problem: &DseProblem,
    kind: StrategyKind,
    iters: usize,
) -> DseOutcome {
    let driver = mk_driver(g);
    let strategy = kind.build(problem.space(), &strategy_cfg());
    driver.run_batched_with(problem, strategy, iters, 2, 12).unwrap()
}

fn run_pipelined(
    g: &GeneratedData,
    problem: &DseProblem,
    kind: StrategyKind,
    iters: usize,
) -> DseOutcome {
    let driver = mk_driver(g);
    let strategy = kind.build(problem.space(), &strategy_cfg());
    driver.run_pipelined_with(problem, strategy, iters, 2, 12, 3).unwrap()
}

fn assert_same(a: &DseOutcome, b: &DseOutcome, label: &str) {
    assert_eq!(a.points, b.points, "{label}: trajectory diverged");
    assert_eq!(a.best, b.best, "{label}: Eq. 3 winners diverged");
    assert_eq!(a.ground_truth_errors, b.ground_truth_errors, "{label}: ground truth diverged");
    assert_eq!(a.pareto_front(), b.pareto_front(), "{label}: Pareto front diverged");
}

#[test]
fn every_strategy_workload_cell_is_deterministic_across_cadences_and_reruns() {
    let cells = [
        (Platform::Axiline, None),
        (Platform::Vta, Some("transformer")),
    ];
    for (platform, workload) in cells {
        let g = gen_data(platform, Enablement::Gf12, workload);
        let problem = problem_for(&g, workload);
        for kind in StrategyKind::ALL {
            let label = format!("{}/{:?}/{}", platform, workload, kind.name());
            let strict = run_strict(&g, &problem, kind, 40);
            assert_eq!(strict.points.len(), 40, "{label}: truncated trajectory");
            let rerun = run_strict(&g, &problem, kind, 40);
            assert_same(&strict, &rerun, &format!("{label} rerun"));
            let piped = run_pipelined(&g, &problem, kind, 40);
            assert_same(&strict, &piped, &format!("{label} pipelined"));
        }
    }
}

#[test]
fn ng45_enablement_cell_is_deterministic_too() {
    // the enablement axis of the grid: same contract on NG45
    let g = gen_data(Platform::Axiline, Enablement::Ng45, None);
    let problem = problem_for(&g, None);
    let strict = run_strict(&g, &problem, StrategyKind::Evo, 40);
    let rerun = run_strict(&g, &problem, StrategyKind::Evo, 40);
    assert_same(&strict, &rerun, "ng45/evo rerun");
    let piped = run_pipelined(&g, &problem, StrategyKind::Evo, 40);
    assert_same(&strict, &piped, "ng45/evo pipelined");
}

#[test]
fn warm_cache_rerun_of_a_matrix_cell_is_byte_identical() {
    let dir = tmp_dir("warm-cell");
    // a thoroughly non-default cell: LHS strategy, GCN workload on VTA
    let g = gen_data(Platform::Vta, Enablement::Gf12, Some("gcn"));
    let problem = problem_for(&g, Some("gcn"));

    let run = |store: &Arc<CacheStore>| {
        let bundle = SurrogateBundle::fit(&g.dataset, &g.backend_split, 1).unwrap();
        let service = EvalService::new(Enablement::Gf12, 2023)
            .with_workers(2)
            .with_surrogate(bundle)
            .with_cache_store(Arc::clone(store));
        let driver = DseDriver { service };
        let strategy = StrategyKind::Lhs.build(problem.space(), &strategy_cfg());
        let out = driver.run_batched_with(&problem, strategy, 40, 2, 12).unwrap();
        let stats = driver.stats();
        driver.service.flush_cache().unwrap();
        (out, stats)
    };

    let (cold, cold_stats) = {
        let store = Arc::new(CacheStore::open(&dir).unwrap());
        run(&store)
    };
    let store = Arc::new(CacheStore::open(&dir).unwrap());
    let (warm, warm_stats) = run(&store);

    assert_same(&cold, &warm, "vta-gcn/lhs warm cache");
    assert!(cold_stats.oracle_misses > 0, "cold run must hit the oracle");
    assert_eq!(cold_stats.disk_hits, 0);
    assert!(warm_stats.disk_hits > 0, "warm run saw no disk hits: {warm_stats}");
    assert_eq!(
        warm_stats.oracle_misses, 0,
        "warm run re-ran the oracle: {warm_stats}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// 2D hypervolume (minimization) against `reference`: the area weakly
/// dominated by the front and bounded by the reference point.
fn hypervolume(points: &[(f64, f64)], reference: (f64, f64)) -> f64 {
    let mut pts: Vec<(f64, f64)> = points
        .iter()
        .copied()
        .filter(|&(x, y)| x < reference.0 && y < reference.1)
        .collect();
    pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.partial_cmp(&b.1).unwrap()));
    let mut hv = 0.0;
    let mut best_y = reference.1;
    for (x, y) in pts {
        if y < best_y {
            hv += (reference.0 - x) * (best_y - y);
            best_y = y;
        }
    }
    hv
}

#[test]
fn motpe_beats_random_search_on_the_same_budget_through_the_driver() {
    // generalizes the in-crate `motpe_beats_random_on_same_budget` unit
    // test to the full DseDriver path: same budget, same seed, same
    // surrogate — MOTPE's feasible predicted-(energy, area) front must
    // dominate more hypervolume than seeded random search
    let g = gen_data(Platform::Axiline, Enablement::Gf12, None);
    let problem = problem_for(&g, None);
    let motpe = run_strict(&g, &problem, StrategyKind::Motpe, 160);
    let random = run_strict(&g, &problem, StrategyKind::Random, 160);

    let objs = |o: &DseOutcome| -> Vec<(f64, f64)> {
        o.points
            .iter()
            .filter(|p| p.feasible)
            .map(|p| (p.predicted[&Metric::Energy], p.predicted[&Metric::Area]))
            .collect()
    };
    let (mo, ro) = (objs(&motpe), objs(&random));
    assert!(!mo.is_empty(), "MOTPE found no feasible points");
    assert!(!ro.is_empty(), "random search found no feasible points");

    // reference point: componentwise worst over both runs, padded so
    // boundary points still contribute volume
    let worst = mo
        .iter()
        .chain(&ro)
        .fold((f64::MIN, f64::MIN), |acc, &(x, y)| (acc.0.max(x), acc.1.max(y)));
    let reference = (worst.0 * 1.1, worst.1 * 1.1);
    let hv_motpe = hypervolume(&mo, reference);
    let hv_random = hypervolume(&ro, reference);
    assert!(
        hv_motpe > hv_random,
        "MOTPE hypervolume {hv_motpe:.4e} must beat random search {hv_random:.4e} \
         on the same {}-evaluation budget",
        motpe.points.len()
    );
}
