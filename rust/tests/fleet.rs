//! Distributed evaluation fleet tests (ISSUE 10): a real `fso fleet
//! lead` child process driving real `fso fleet work` child processes
//! over TCP, proving the fleet's two headline contracts:
//!
//! * determinism — a fixed seed produces byte-identical experiment
//!   CSVs and flushed store shards whether the oracle runs in-process
//!   (`fso dse`) or across 1, 2, or 4 workers;
//! * recovery — a worker killed between claim and result has its
//!   lease expire and its key requeued, and the run still matches the
//!   single-process bytes.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, ExitStatus, Stdio};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Shared experiment knobs: the smallest fig11 run that still sweeps
/// datagen + surrogate fit + DSE ground-truthing through the oracle.
const KNOBS: [&str; 9] = [
    "--target",
    "axiline-svm",
    "--quick",
    "--archs",
    "4",
    "--iters",
    "24",
    "--seed",
    "2023",
];

fn fso() -> Command {
    Command::new(env!("CARGO_BIN_EXE_fso"))
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fso-fleet-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The single-process reference run: `fso dse` with the exact knobs
/// the leader gets.
fn run_single(out: &Path, cache: &Path) {
    let o = fso()
        .arg("dse")
        .args(KNOBS)
        .arg("--out-dir")
        .arg(out)
        .arg("--cache-dir")
        .arg(cache)
        .stdin(Stdio::null())
        .output()
        .expect("run fso dse");
    assert!(
        o.status.success(),
        "single-process dse failed:\n{}",
        String::from_utf8_lossy(&o.stderr)
    );
}

struct Leader {
    child: Child,
    addr: String,
    stderr: Arc<Mutex<String>>,
    stderr_drain: Option<std::thread::JoinHandle<()>>,
}

impl Leader {
    /// Spawn `fso fleet lead --listen 127.0.0.1:0 <knobs>`, parse the
    /// bound address off the first stdout line, and park reader
    /// threads on both pipes so the experiment's prints can never fill
    /// a pipe and stall the leader.
    fn start(out: &Path, cache: &Path, lease_ms: Option<&str>) -> Leader {
        let mut cmd = fso();
        cmd.args(["fleet", "lead", "--listen", "127.0.0.1:0"]);
        cmd.args(KNOBS);
        cmd.arg("--out-dir").arg(out).arg("--cache-dir").arg(cache);
        if let Some(ms) = lease_ms {
            cmd.args(["--lease-ms", ms]);
        }
        cmd.stdin(Stdio::null()).stdout(Stdio::piped()).stderr(Stdio::piped());
        let mut child = cmd.spawn().expect("spawn fso fleet lead");
        let mut rdr = BufReader::new(child.stdout.take().expect("leader stdout"));
        let mut line = String::new();
        rdr.read_line(&mut line).expect("leader bind line");
        let addr = line
            .trim()
            .strip_prefix("listening on ")
            .unwrap_or_else(|| panic!("unexpected leader banner: {line:?}"))
            .to_string();
        std::thread::spawn(move || {
            let mut sink = String::new();
            let _ = rdr.read_to_string(&mut sink);
        });
        let stderr = Arc::new(Mutex::new(String::new()));
        let pipe = child.stderr.take().expect("leader stderr");
        let stderr_drain = {
            let stderr = Arc::clone(&stderr);
            std::thread::spawn(move || {
                let mut text = String::new();
                let _ = BufReader::new(pipe).read_to_string(&mut text);
                stderr.lock().unwrap().push_str(&text);
            })
        };
        Leader { child, addr, stderr, stderr_drain: Some(stderr_drain) }
    }

    fn wait_success(&mut self, limit: Duration) {
        let t0 = Instant::now();
        loop {
            if let Some(status) = self.child.try_wait().expect("try_wait leader") {
                // the pipe EOFs once the process is gone — join the
                // drain thread so `stderr` holds the complete log
                // before any assertion reads it
                if let Some(h) = self.stderr_drain.take() {
                    let _ = h.join();
                }
                assert!(
                    status.success(),
                    "leader failed ({status}):\n{}",
                    self.stderr.lock().unwrap()
                );
                return;
            }
            assert!(
                t0.elapsed() < limit,
                "leader did not finish within {limit:?}:\n{}",
                self.stderr.lock().unwrap()
            );
            std::thread::sleep(Duration::from_millis(50));
        }
    }

    fn stderr_text(&self) -> String {
        self.stderr.lock().unwrap().clone()
    }
}

impl Drop for Leader {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn spawn_worker(addr: &str, exit_after: Option<&str>) -> Child {
    let mut cmd = fso();
    cmd.args(["fleet", "work", "--connect", addr]);
    if let Some(n) = exit_after {
        cmd.args(["--exit-after", n]);
    }
    cmd.stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn fso fleet work")
}

fn wait_exit(mut child: Child, limit: Duration) -> ExitStatus {
    let t0 = Instant::now();
    loop {
        if let Some(status) = child.try_wait().expect("try_wait worker") {
            return status;
        }
        if t0.elapsed() >= limit {
            let _ = child.kill();
            let _ = child.wait();
            panic!("worker did not exit within {limit:?}");
        }
        std::thread::sleep(Duration::from_millis(25));
    }
}

/// Every file under a store directory (recursive), keyed by relative
/// path — minus the lock files, whose content is the owning process id
/// and legitimately differs.
fn store_files(dir: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(root: &Path, dir: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        let Ok(rd) = std::fs::read_dir(dir) else { return };
        for entry in rd.flatten() {
            let path = entry.path();
            if path.is_dir() {
                walk(root, &path, out);
            } else {
                let rel = path.strip_prefix(root).unwrap().to_string_lossy().into_owned();
                if rel.ends_with(".lock") {
                    continue;
                }
                out.insert(rel, std::fs::read(&path).expect("read store file"));
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(dir, dir, &mut out);
    out
}

#[test]
fn fleet_matches_the_single_process_run_byte_for_byte_at_any_worker_count() {
    let base_out = tmp_dir("base-out");
    let base_cache = tmp_dir("base-cache");
    run_single(&base_out, &base_cache);
    let want_csv = std::fs::read(base_out.join("fig11.csv")).expect("baseline fig11.csv");
    let want_store = store_files(&base_cache);
    assert!(!want_store.is_empty(), "baseline run must flush a store");

    for n in [1usize, 2, 4] {
        let out = tmp_dir(&format!("w{n}-out"));
        let cache = tmp_dir(&format!("w{n}-cache"));
        let mut leader = Leader::start(&out, &cache, None);
        let workers: Vec<Child> =
            (0..n).map(|_| spawn_worker(&leader.addr, None)).collect();
        leader.wait_success(Duration::from_secs(300));
        // workers see the drain (claim answered with drain:true, or
        // EOF once the listener joins) and exit clean on their own
        for w in workers {
            let status = wait_exit(w, Duration::from_secs(30));
            assert!(status.success(), "worker must exit clean after drain: {status}");
        }
        let got_csv = std::fs::read(out.join("fig11.csv")).expect("fleet fig11.csv");
        assert_eq!(
            got_csv, want_csv,
            "fig11.csv must be byte-identical with {n} worker(s)"
        );
        assert_eq!(
            store_files(&cache),
            want_store,
            "flushed store shards must be byte-identical with {n} worker(s)"
        );
        let _ = std::fs::remove_dir_all(&out);
        let _ = std::fs::remove_dir_all(&cache);
    }
    let _ = std::fs::remove_dir_all(&base_out);
    let _ = std::fs::remove_dir_all(&base_cache);
}

#[test]
fn a_killed_workers_lease_expires_requeues_and_the_run_still_matches() {
    let base_out = tmp_dir("kill-base-out");
    let base_cache = tmp_dir("kill-base-cache");
    run_single(&base_out, &base_cache);
    let want_csv = std::fs::read(base_out.join("fig11.csv")).expect("baseline fig11.csv");
    let want_store = store_files(&base_cache);

    let out = tmp_dir("kill-out");
    let cache = tmp_dir("kill-cache");
    // short lease so the casualty's abandoned claim requeues fast
    let mut leader = Leader::start(&out, &cache, Some("300"));
    let casualty = spawn_worker(&leader.addr, Some("1"));
    let survivor = spawn_worker(&leader.addr, None);
    leader.wait_success(Duration::from_secs(300));

    let died = wait_exit(casualty, Duration::from_secs(30));
    assert_eq!(
        died.code(),
        Some(17),
        "--exit-after worker must die with its marker code, got {died}"
    );
    let status = wait_exit(survivor, Duration::from_secs(30));
    assert!(status.success(), "surviving worker must exit clean: {status}");

    let stderr = leader.stderr_text();
    let summary = stderr
        .lines()
        .find(|l| l.contains("[fleet] leader down"))
        .unwrap_or_else(|| panic!("no leader summary in stderr:\n{stderr}"));
    let requeues: usize = summary
        .split("requeues=")
        .nth(1)
        .and_then(|s| s.split_whitespace().next())
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("unparseable leader summary: {summary}"));
    assert!(requeues >= 1, "the abandoned claim must be requeued: {summary}");

    let got_csv = std::fs::read(out.join("fig11.csv")).expect("fleet fig11.csv");
    assert_eq!(got_csv, want_csv, "fig11.csv must survive a worker death byte-for-byte");
    assert_eq!(
        store_files(&cache),
        want_store,
        "flushed store shards must survive a worker death byte-for-byte"
    );

    for d in [&base_out, &base_cache, &out, &cache] {
        let _ = std::fs::remove_dir_all(d);
    }
}
