//! Surrogate-model persistence (ISSUE 3): every serializable model
//! family round-trips through the model store with bit-exact
//! predictions; corrupt artifacts fall back to refitting (and are
//! repaired); a warm `Trainer` run reports zero refits and zero
//! tuning-search evaluations with identical reports.

use std::path::PathBuf;
use std::sync::Arc;

use fso::backend::Enablement;
use fso::coordinator::dse_driver::SurrogateBundle;
use fso::coordinator::{datagen, DatagenConfig, ModelKey, ModelStore, Trainer};
use fso::coordinator::{ModelMenu, TrainOptions};
use fso::generators::Platform;
use fso::models::{
    tune_gbdt, tune_rf, BasePredictions, Gbdt, GbdtClassifier, GbdtParams, RandomForest,
    RegTree, RfParams, Ridge, RoiClassifier, SearchBudget, StackedEnsemble, TreeParams,
    TunedGbdt, TunedRf,
};
use fso::util::json::Json;
use fso::util::rng::Rng;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("fso-modelstore-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Synthetic regression data with interactions and a held-out matrix.
fn toy(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let x: Vec<Vec<f64>> =
        (0..n).map(|_| (0..6).map(|_| rng.f64()).collect()).collect();
    let y: Vec<f64> =
        x.iter().map(|v| 4.0 * v[0] * v[1] + v[2] - 2.0 * v[3] + 0.1 * v[4]).collect();
    (x, y)
}

/// Serialize -> print -> parse -> deserialize: the exact disk path.
fn disk_roundtrip(j: Json) -> Json {
    Json::parse(&j.to_string()).expect("serialized model must re-parse")
}

#[test]
fn every_model_family_round_trips_with_bit_exact_predictions() {
    let (x, y) = toy(200, 1);
    let (x_hold, y_hold) = toy(60, 2);

    // decision tree
    let idx: Vec<usize> = (0..x.len()).collect();
    let tree = RegTree::fit(&x, &y, &idx, TreeParams::default(), &mut Rng::new(3));
    let tree2 = RegTree::from_json(&disk_roundtrip(tree.to_json())).expect("tree");
    for xi in &x_hold {
        assert_eq!(tree.predict(xi).to_bits(), tree2.predict(xi).to_bits());
    }

    // GBDT regressor
    let gbdt = Gbdt::fit(&x, &y, GbdtParams { n_estimators: 40, ..Default::default() }, 5);
    let gbdt2 = Gbdt::from_json(&disk_roundtrip(gbdt.to_json())).expect("gbdt");
    for (a, b) in gbdt.predict(&x_hold).iter().zip(gbdt2.predict(&x_hold)) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    // GBDT classifier (the two-stage ROI stage 1)
    let labels: Vec<bool> = y.iter().map(|&v| v > 1.5).collect();
    let cls = GbdtClassifier::fit(
        &x,
        &labels,
        GbdtParams { n_estimators: 40, ..Default::default() },
        5,
    );
    let cls2 = GbdtClassifier::from_json(&disk_roundtrip(cls.to_json())).expect("classifier");
    for xi in &x_hold {
        assert_eq!(cls.prob_one(xi).to_bits(), cls2.prob_one(xi).to_bits());
    }

    // random forest
    let rf = RandomForest::fit(
        &x,
        &y,
        RfParams { n_estimators: 30, ..Default::default() },
        5,
    );
    let rf2 = RandomForest::from_json(&disk_roundtrip(rf.to_json())).expect("rf");
    for (a, b) in rf.predict(&x_hold).iter().zip(rf2.predict(&x_hold)) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    // ridge (linear)
    let ridge = Ridge::fit(&x, &y, 1e-3);
    let ridge2 = Ridge::from_json(&disk_roundtrip(ridge.to_json())).expect("ridge");
    for (a, b) in ridge.predict(&x_hold).iter().zip(ridge2.predict(&x_hold)) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    // two-stage ROI classifier
    let roi = RoiClassifier::fit(&x, &labels, 5);
    let roi2 = RoiClassifier::from_json(&disk_roundtrip(roi.to_json())).expect("roi");
    for xi in &x_hold {
        assert_eq!(roi.prob(xi).to_bits(), roi2.prob(xi).to_bits());
    }

    // tuned GBDT / RF (the tuning-search outcomes the trainer persists)
    let budget = SearchBudget { stage1: 3, stage2: 2, seed: 1 };
    let tg = tune_gbdt(&x, &y, &x_hold, &y_hold, budget);
    let tg2 = TunedGbdt::from_json(&disk_roundtrip(tg.to_json())).expect("tuned gbdt");
    assert_eq!(tg.val_rmse.to_bits(), tg2.val_rmse.to_bits());
    for (a, b) in tg.model.predict(&x_hold).iter().zip(tg2.model.predict(&x_hold)) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    let tr = tune_rf(&x, &y, &x_hold, &y_hold, budget);
    let tr2 = TunedRf::from_json(&disk_roundtrip(tr.to_json())).expect("tuned rf");
    assert_eq!(tr.params.max_depth, tr2.params.max_depth);
    for (a, b) in tr.model.predict(&x_hold).iter().zip(tr2.model.predict(&x_hold)) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    // stacked ensemble
    let bases = vec![
        BasePredictions {
            name: "GBDT".into(),
            val: gbdt.predict(&x_hold),
            test: gbdt.predict(&x_hold),
        },
        BasePredictions {
            name: "RF".into(),
            val: rf.predict(&x_hold),
            test: rf.predict(&x_hold),
        },
    ];
    let ens = StackedEnsemble::fit(&bases, &y_hold).unwrap();
    let ens2 = StackedEnsemble::from_json(&disk_roundtrip(ens.to_json())).expect("ensemble");
    assert_eq!(ens.base_names, ens2.base_names);
    for (a, b) in ens.predict(&bases).iter().zip(ens2.predict(&bases)) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

fn small_cfg() -> DatagenConfig {
    DatagenConfig {
        n_arch: 6,
        n_backend_train: 10,
        n_backend_test: 4,
        ..DatagenConfig::small(Platform::Axiline, Enablement::Gf12)
    }
}

#[test]
fn surrogate_bundle_persists_and_replays_bit_identically() {
    let dir = tmp_dir("bundle");
    let g = datagen::generate(&small_cfg()).unwrap();
    let feats: Vec<Vec<f64>> = g.dataset.rows.iter().map(|r| r.features_vec()).collect();

    let cold_preds = {
        let store = ModelStore::open(&dir).unwrap();
        let (bundle, replayed) =
            SurrogateBundle::fit_cached(&g.dataset, &g.backend_split, 7, Some(&store))
                .unwrap();
        assert!(!replayed, "empty store cannot replay");
        store.flush().unwrap();
        bundle.predict_batch(&feats, 1)
    };

    let store = ModelStore::open(&dir).unwrap();
    let (bundle, replayed) =
        SurrogateBundle::fit_cached(&g.dataset, &g.backend_split, 7, Some(&store)).unwrap();
    assert!(replayed, "reopened store must serve the artifact");
    assert_eq!(store.hits(), 1);
    let warm_preds = bundle.predict_batch(&feats, 1);
    assert_eq!(cold_preds.len(), warm_preds.len());
    for ((roi_a, pred_a), (roi_b, pred_b)) in cold_preds.iter().zip(&warm_preds) {
        assert_eq!(roi_a, roi_b, "ROI gate must replay identically");
        for (m, va) in pred_a {
            assert_eq!(
                va.to_bits(),
                pred_b[m].to_bits(),
                "{m}: stored bundle must replay bit-identical predictions"
            );
        }
    }

    // a different seed is a different artifact, not a collision
    let (_, replayed) =
        SurrogateBundle::fit_cached(&g.dataset, &g.backend_split, 8, Some(&store)).unwrap();
    assert!(!replayed, "seed is part of the content-hash key");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_artifact_falls_back_to_refit_and_is_repaired() {
    let dir = tmp_dir("corrupt");
    let g = datagen::generate(&small_cfg()).unwrap();
    let key = SurrogateBundle::store_key(&g.dataset, &g.backend_split, 7);

    // plant a structurally-valid record whose payload is garbage
    {
        let store = ModelStore::open(&dir).unwrap();
        store.put(
            SurrogateBundle::STORE_KIND,
            key,
            Json::obj(vec![("bogus", true.into())]),
        );
        store.flush().unwrap();
    }
    {
        let store = ModelStore::open(&dir).unwrap();
        let (_, replayed) =
            SurrogateBundle::fit_cached(&g.dataset, &g.backend_split, 7, Some(&store))
                .unwrap();
        assert!(!replayed, "corrupt artifact must fall back to a refit");
        store.flush().unwrap(); // the refit's write-behind repairs the record
    }
    let store = ModelStore::open(&dir).unwrap();
    let (_, replayed) =
        SurrogateBundle::fit_cached(&g.dataset, &g.backend_split, 7, Some(&store)).unwrap();
    assert!(replayed, "the repaired artifact must replay on the next warm start");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_trainer_run_skips_all_tuning_and_reports_identically() {
    let dir = tmp_dir("trainer");
    // sizes mirror tests/pipeline_smoke.rs, known to leave ROI rows in
    // both the training and the carved validation parts
    let g = datagen::generate(&DatagenConfig {
        n_arch: 8,
        n_backend_train: 12,
        n_backend_test: 4,
        ..DatagenConfig::small(Platform::Axiline, Enablement::Gf12)
    })
    .unwrap();
    let opts = TrainOptions {
        menu: ModelMenu::trees_only(),
        search: SearchBudget { stage1: 3, stage2: 2, seed: 1 },
        seed: 7,
        ..Default::default()
    };
    let metric = fso::data::Metric::Power;

    let cold = {
        let store = Arc::new(ModelStore::open_under(&dir).unwrap());
        let trainer = Trainer::new(None).with_model_store(store.clone());
        let report = trainer.run(&g.dataset, &g.backend_split, metric, &opts).unwrap();
        store.flush().unwrap();
        report
    };
    assert!(cold.model_cache.refits > 0, "cold run must fit fresh models");
    assert!(cold.model_cache.tuning_evals > 0, "cold run must run tuning searches");

    let store = Arc::new(ModelStore::open_under(&dir).unwrap());
    let trainer = Trainer::new(None).with_model_store(store.clone());
    let warm = trainer.run(&g.dataset, &g.backend_split, metric, &opts).unwrap();

    // ISSUE 3 acceptance: zero refits, zero tuning-search evaluations
    assert_eq!(warm.model_cache.refits, 0, "warm run refit: {:?}", warm.model_cache);
    assert_eq!(warm.model_cache.tuning_evals, 0);
    assert_eq!(warm.model_cache.cached, 3, "classifier + tuned GBDT + tuned RF");

    // and the report replays identically (bit-exact model predictions)
    assert_eq!(cold.roi, warm.roi);
    assert_eq!(cold.eval_rows, warm.eval_rows);
    assert_eq!(cold.models, warm.models, "cold and warm reports diverged");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn model_keys_fingerprint_dataset_split_metric_and_seed() {
    let g = datagen::generate(&small_cfg()).unwrap();
    let k = |seed| SurrogateBundle::store_key(&g.dataset, &g.backend_split, seed);
    assert_eq!(k(7), k(7), "keys are deterministic");
    assert_ne!(k(7), k(8), "seed changes the key");
    let mut other_split = g.backend_split.clone();
    other_split.train.truncate(other_split.train.len() - 1);
    assert_ne!(
        k(7),
        SurrogateBundle::store_key(&g.dataset, &other_split, 7),
        "split changes the key"
    );
    // raw ModelKey: tag + matrix shape discrimination
    assert_ne!(
        ModelKey::new("a").rows(&[vec![1.0], vec![2.0]]).finish(),
        ModelKey::new("a").rows(&[vec![1.0, 2.0]]).finish(),
    );
}
