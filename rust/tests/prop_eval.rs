//! Property-based invariants for the evaluation-service PR (ISSUE 1),
//! via the in-repo mini property harness (`util::prop`): Pareto
//! non-domination, sampler output ranges/dimensionality, `par_map`
//! order preservation, and eval-service cache consistency.

use fso::backend::{BackendConfig, Enablement};
use fso::coordinator::EvalService;
use fso::dse::{dominates, nondominated_rank, pareto_front};
use fso::generators::{ArchConfig, Platform};
use fso::sampling::{Sampler, SamplerKind};
use fso::util::pool::par_map;
use fso::util::prop::check;

#[test]
fn prop_pareto_front_nondominated_and_consistent_with_rank0() {
    check(200, 0xFA57, |rng| {
        let n = 1 + rng.below(60);
        let dims = 2 + rng.below(3);
        // mix continuous values with a coarse grid so exact ties and
        // duplicated points are exercised too
        let pts: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                (0..dims)
                    .map(|_| {
                        if rng.bool(0.3) {
                            rng.below(4) as f64
                        } else {
                            rng.range(0.0, 4.0)
                        }
                    })
                    .collect()
            })
            .collect();
        let front = pareto_front(&pts);
        assert!(!front.is_empty(), "a non-empty set always has a front");
        // no front member dominates another, and nothing dominates a member
        for &i in &front {
            for (j, p) in pts.iter().enumerate() {
                if j != i {
                    assert!(!dominates(p, &pts[i]), "front member {i} dominated by {j}");
                }
            }
        }
        // rank 0 of the non-dominated sort is exactly the front
        let ranks = nondominated_rank(&pts);
        let rank0: Vec<usize> = (0..n).filter(|&i| ranks[i] == 0).collect();
        assert_eq!(front, rank0, "pareto_front and nondominated_rank disagree");
    });
}

#[test]
fn prop_sampler_outputs_unit_interval_with_correct_dimensionality() {
    check(120, 0x5A11, |rng| {
        let dim = 1 + rng.below(10);
        let n = 1 + rng.below(48);
        let kind = SamplerKind::ALL[rng.below(3)];
        let mut s = Sampler::new(kind, dim, rng.next_u64());
        let pts = s.sample(n);
        assert_eq!(pts.len(), n, "{kind:?}: wrong point count");
        for p in &pts {
            assert_eq!(p.len(), dim, "{kind:?}: wrong dimensionality");
            for &x in p {
                assert!((0.0..1.0).contains(&x), "{kind:?}: {x} outside [0,1)");
            }
        }
    });
}

#[test]
fn prop_par_map_preserves_order_for_any_worker_count() {
    check(150, 0x9A9, |rng| {
        let n = rng.below(200);
        let workers = 1 + rng.below(8);
        let k = rng.next_u64();
        let out = par_map(n, workers, |i| i as u64 * 31 + k);
        let expect: Vec<u64> = (0..n).map(|i| i as u64 * 31 + k).collect();
        assert_eq!(out, expect);
    });
}

#[test]
fn prop_eval_service_cache_is_transparent() {
    check(24, 0xCAC4E, |rng| {
        let p = Platform::ALL[rng.below(4)];
        let arch = ArchConfig::new(
            p,
            p.param_space().iter().map(|s| s.kind.from_unit(rng.f64())).collect(),
        );
        let bcfg = BackendConfig::new(rng.range(0.3, 1.8), rng.range(0.25, 0.7));
        let svc = EvalService::new(Enablement::Gf12, rng.next_u64());
        let first = svc.evaluate(&arch, bcfg, None).unwrap();
        let second = svc.evaluate(&arch, bcfg, None).unwrap();
        assert_eq!(first.flow.backend, second.flow.backend);
        assert_eq!(first.system, second.system);
        let stats = svc.stats();
        assert_eq!(stats.oracle_misses, 1, "cache missed twice");
        assert_eq!(stats.oracle_hits, 1, "repeat not served from cache");
    });
}

#[test]
fn prop_evaluate_many_equals_pointwise_evaluate() {
    check(16, 0xEBA1, |rng| {
        let p = Platform::Axiline;
        let jobs: Vec<(ArchConfig, BackendConfig)> = (0..1 + rng.below(8))
            .map(|_| {
                let arch = ArchConfig::new(
                    p,
                    p.param_space().iter().map(|s| s.kind.from_unit(rng.f64())).collect(),
                );
                (arch, BackendConfig::new(rng.range(0.4, 2.0), rng.range(0.4, 0.85)))
            })
            .collect();
        let seed = rng.next_u64();
        let pooled = EvalService::new(Enablement::Gf12, seed).with_workers(4);
        let solo = EvalService::new(Enablement::Gf12, seed);
        let batch = pooled.evaluate_many(&jobs, None).unwrap();
        assert_eq!(batch.len(), jobs.len());
        for ((arch, bcfg), ev) in jobs.iter().zip(&batch) {
            let one = solo.evaluate(arch, *bcfg, None).unwrap();
            assert_eq!(one.flow.backend, ev.flow.backend);
            assert_eq!(one.system, ev.system);
        }
    });
}
