//! Concurrency tests for the shared store core (ISSUE 4 satellite):
//! writers flushing one store directory at the same time — in-process
//! threads over separate store instances, and two spawned `fso
//! datagen` processes sharing `--cache-dir` — must end with shards
//! holding the *union* of everything written (merge-on-flush +
//! `.store.lock` ordering; no lost updates).

use std::fs;
use std::path::PathBuf;
use std::process::Command;

use fso::coordinator::{CacheStore, ModelStore};
use fso::util::json::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("fso-store-conc-{}-{tag}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    d
}

fn payload(v: f64) -> Json {
    Json::obj(vec![("w", Json::arr_f64(&[v])), ("b", v.into())])
}

#[test]
fn two_threads_flushing_one_dir_keep_the_union() {
    let dir = tmp_dir("threads");
    let n = 40u64;
    // same top byte -> same shard: maximal flush contention
    let key_a = |i: u64| 0x1100_0000_0000_0000 | (2 * i + 1);
    let key_b = |i: u64| 0x1100_0000_0000_0000 | (2 * i + 2);
    std::thread::scope(|scope| {
        let dir_a = dir.clone();
        let dir_b = dir.clone();
        scope.spawn(move || {
            let store = ModelStore::open(&dir_a).unwrap();
            for i in 0..n {
                store.put("f", key_a(i), payload(i as f64));
                if i % 8 == 7 {
                    store.flush().unwrap();
                }
            }
            store.flush().unwrap();
        });
        scope.spawn(move || {
            let store = ModelStore::open(&dir_b).unwrap();
            for i in 0..n {
                store.put("f", key_b(i), payload(-(i as f64)));
                if i % 8 == 7 {
                    store.flush().unwrap();
                }
            }
            store.flush().unwrap();
        });
    });
    let store = ModelStore::open(&dir).unwrap();
    for i in 0..n {
        assert_eq!(
            store.get("f", key_a(i)),
            Some(payload(i as f64)),
            "writer A's record {i} lost in concurrent flushing"
        );
        assert_eq!(
            store.get("f", key_b(i)),
            Some(payload(-(i as f64))),
            "writer B's record {i} lost in concurrent flushing"
        );
    }
    assert_eq!(store.stats().entries, 2 * n as usize);
    assert!(
        !dir.join(".store.lock").exists(),
        "all flushes must release the directory lock"
    );
    let _ = fs::remove_dir_all(&dir);
}

fn datagen_cmd(enablement: &str, cache_dir: &PathBuf) -> Command {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_fso"));
    cmd.args([
        "datagen",
        "--platform",
        "axiline",
        "--archs",
        "2",
        "--seed",
        "7",
        "--enablement",
        enablement,
        "--cache-dir",
    ])
    .arg(cache_dir);
    cmd
}

fn live_entries(dir: &PathBuf) -> usize {
    let store = CacheStore::open(dir).unwrap();
    store.load_all();
    store.stats().entries
}

#[test]
fn spawned_datagen_pair_sharing_cache_dir_merges_both_writers() {
    // solo baselines: what each enablement writes on its own
    let dir_gf = tmp_dir("solo-gf12");
    let dir_ng = tmp_dir("solo-ng45");
    let out = datagen_cmd("gf12", &dir_gf).output().expect("spawn fso datagen");
    assert!(out.status.success(), "solo gf12 datagen failed: {out:?}");
    let out = datagen_cmd("ng45", &dir_ng).output().expect("spawn fso datagen");
    assert!(out.status.success(), "solo ng45 datagen failed: {out:?}");
    let solo_gf = live_entries(&dir_gf);
    let solo_ng = live_entries(&dir_ng);
    assert!(solo_gf > 0 && solo_ng > 0, "solo runs must populate their stores");

    // the race: two processes, one cache dir, concurrent flushes
    let shared = tmp_dir("shared");
    let mut a = datagen_cmd("gf12", &shared).spawn().expect("spawn fso datagen");
    let mut b = datagen_cmd("ng45", &shared).spawn().expect("spawn fso datagen");
    let sa = a.wait().expect("wait gf12");
    let sb = b.wait().expect("wait ng45");
    assert!(sa.success() && sb.success(), "concurrent datagen pair failed");

    // enablement is part of every content-hash key, so the two key
    // sets are disjoint and the merged store must hold exactly the sum
    assert_eq!(
        live_entries(&shared),
        solo_gf + solo_ng,
        "concurrent flushes dropped records (lost update)"
    );
    assert!(
        !shared.join(".store.lock").exists(),
        "both processes must release the directory lock"
    );

    // a warm rerun over the shared dir replays entirely from disk
    let out = datagen_cmd("gf12", &shared).output().expect("spawn warm datagen");
    assert!(out.status.success(), "warm datagen failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("100.0% cached"),
        "warm rerun must be fully cached:\n{stdout}"
    );
    assert!(
        !stdout.contains("persistent 0 disk hits"),
        "warm rerun must hit the persistent store:\n{stdout}"
    );

    let _ = fs::remove_dir_all(&dir_gf);
    let _ = fs::remove_dir_all(&dir_ng);
    let _ = fs::remove_dir_all(&shared);
}
