//! Lifecycle tests for the evaluation daemon (ISSUE 9): a real `fso
//! serve --listen` child process on an ephemeral port, driven by real
//! `fso client` child processes over TCP, proving the daemon's four
//! headline contracts:
//!
//! * determinism — concurrent clients with duplicate-heavy key sets
//!   get byte-identical response lines, identical to a serial client
//!   against a fresh daemon at the same seed;
//! * cross-client dedup — `oracle_runs == unique keys` and
//!   `coalesced_hits > 0` under a hook-forced coalescing window;
//! * admission — a zero-rate token bucket rejects exactly the
//!   requests past its burst, per connection, with 429 responses;
//! * graceful drain — SIGTERM and the `shutdown` op leave
//!   byte-identical flushed stores, and torn/oversized request lines
//!   get error responses while the daemon keeps serving.

use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use fso::generators::Platform;
use fso::util::json::Json;

struct Daemon {
    child: Child,
    addr: String,
}

impl Daemon {
    /// Spawn `fso serve --listen 127.0.0.1:0 --seed 2023 <extra>` and
    /// parse the bound address off its one stdout line.
    fn start(extra: &[&str], test_hooks: bool) -> Daemon {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_fso"));
        cmd.args(["serve", "--listen", "127.0.0.1:0", "--seed", "2023"]);
        cmd.args(extra);
        if test_hooks {
            cmd.env("FSO_SERVE_TEST_HOOKS", "1");
        }
        cmd.stdin(Stdio::null()).stdout(Stdio::piped()).stderr(Stdio::null());
        let mut child = cmd.spawn().expect("spawn fso serve");
        let mut line = String::new();
        BufReader::new(child.stdout.take().expect("daemon stdout"))
            .read_line(&mut line)
            .expect("daemon bind line");
        let addr = line
            .trim()
            .strip_prefix("listening on ")
            .unwrap_or_else(|| panic!("unexpected daemon banner: {line:?}"))
            .to_string();
        Daemon { child, addr }
    }

    /// Spawn an `fso client` child wired to this daemon, with `text`
    /// already written to its stdin (one request per line).
    fn spawn_client(&self, text: &str) -> Child {
        let mut child = Command::new(env!("CARGO_BIN_EXE_fso"))
            .args(["client", "--connect", &self.addr])
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn fso client");
        child
            .stdin
            .take()
            .expect("client stdin")
            .write_all(text.as_bytes())
            .expect("write client requests");
        child
    }

    /// One serial client conversation: requests in, response text out.
    fn run_client(&self, text: &str) -> String {
        let out = self.spawn_client(text).wait_with_output().expect("client run");
        assert!(out.status.success(), "fso client failed: {out:?}");
        String::from_utf8(out.stdout).expect("client responses are UTF-8")
    }

    fn pid(&self) -> u32 {
        self.child.id()
    }

    /// Wait for the daemon to exit on its own (post-drain).
    fn wait_exit(&mut self, limit: Duration) {
        let t0 = Instant::now();
        loop {
            if self.child.try_wait().expect("try_wait daemon").is_some() {
                return;
            }
            assert!(t0.elapsed() < limit, "daemon did not drain within {limit:?}");
            std::thread::sleep(Duration::from_millis(25));
        }
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn request(id: usize, op: &str, body: Json) -> String {
    let mut line = Json::obj(vec![
        ("body", body),
        ("id", Json::from(id)),
        ("op", Json::from(op)),
    ])
    .to_string();
    line.push('\n');
    line
}

/// A valid Axiline eval request: every parameter mapped from one unit
/// coordinate, so distinct `u` values give distinct oracle keys.
fn eval_request(id: usize, u: f64) -> String {
    let values: Vec<f64> =
        Platform::Axiline.param_space().iter().map(|p| p.kind.from_unit(u)).collect();
    request(
        id,
        "eval",
        Json::obj(vec![
            ("arch", Json::arr_f64(&values)),
            ("f", Json::from(0.7)),
            ("platform", Json::from("axiline")),
            ("util", Json::from(0.55)),
        ]),
    )
}

fn parse_line(line: &str) -> Json {
    Json::parse(line).unwrap_or_else(|e| panic!("bad response line {line:?}: {e}"))
}

/// The duplicate-heavy shared workload: 4 unique keys, each requested
/// twice per client (ids fixed per position, so responses are
/// comparable byte-for-byte across clients).
fn duplicate_heavy_workload() -> String {
    const UNITS: [f64; 4] = [0.1, 0.35, 0.6, 0.85];
    let mut text = String::new();
    for (i, u) in UNITS.iter().chain(UNITS.iter()).enumerate() {
        text.push_str(&eval_request(i + 1, *u));
    }
    text
}

#[test]
fn concurrent_clients_get_byte_identical_responses_and_share_oracle_runs() {
    let daemon = Daemon::start(&[], true);
    // force a coalescing window: the next single-flight leader holds
    // until two waiters queue on its flight, so the three clients'
    // first (identical) eval provably coalesces instead of racing the
    // memo
    let armed = daemon.run_client(&request(
        1,
        "hook",
        Json::obj(vec![("kind", Json::from("leader_barrier")), ("n", Json::from(2.0))]),
    ));
    assert_eq!(parse_line(armed.trim()).get("ok").as_bool(), Some(true));

    let workload = duplicate_heavy_workload();
    let clients: Vec<Child> = (0..3).map(|_| daemon.spawn_client(&workload)).collect();
    let outputs: Vec<String> = clients
        .into_iter()
        .map(|c| {
            let out = c.wait_with_output().expect("client run");
            assert!(out.status.success(), "fso client failed: {out:?}");
            String::from_utf8(out.stdout).expect("UTF-8 responses")
        })
        .collect();
    assert!(!outputs[0].is_empty());
    assert_eq!(outputs[0], outputs[1], "clients 1 and 2 diverged");
    assert_eq!(outputs[0], outputs[2], "clients 1 and 3 diverged");
    for line in outputs[0].lines() {
        assert_eq!(parse_line(line).get("ok").as_bool(), Some(true), "in {line:?}");
    }

    // cross-client dedup, straight from the daemon's own counters
    let stats = daemon.run_client(&request(50, "stats", Json::Null));
    let body = parse_line(stats.trim());
    let body = body.get("body");
    let runs = body.get("oracle_runs").as_usize().unwrap();
    let hits = body.get("oracle_hits").as_usize().unwrap();
    let coalesced = body.get("coalesced_hits").as_usize().unwrap();
    assert_eq!(runs, 4, "oracle ran once per unique key, nothing more");
    assert_eq!(hits + coalesced, 3 * 8 - 4, "every duplicate was served without a rerun");
    assert!(coalesced > 0, "the barrier-held flight must absorb waiters in flight");

    // a serial client against a fresh daemon at the same seed returns
    // the same bytes: concurrency changed nothing observable
    let serial = Daemon::start(&[], false);
    assert_eq!(serial.run_client(&workload), outputs[0], "serial run diverged");
}

#[test]
fn quota_rejects_exactly_past_burst_per_connection() {
    let daemon = Daemon::start(&["--quota-burst", "3"], false);
    let text: String = (1..=8).map(|id| request(id, "health", Json::Null)).collect();
    let run = |d: &Daemon| -> Vec<(bool, usize, usize)> {
        d.run_client(&text)
            .lines()
            .map(|l| {
                let j = parse_line(l);
                (
                    j.get("ok").as_bool().unwrap(),
                    j.get("id").as_usize().unwrap(),
                    j.get("code").as_usize().unwrap_or(0),
                )
            })
            .collect()
    };
    let first = run(&daemon);
    assert_eq!(first.len(), 8);
    for (i, (ok, id, code)) in first.iter().enumerate() {
        assert_eq!(*id, i + 1, "response ids echo request ids in order");
        if i < 3 {
            assert!(*ok, "request {} within burst must succeed", i + 1);
        } else {
            assert!(!*ok, "request {} past burst must be rejected", i + 1);
            assert_eq!(*code, 429);
        }
    }
    // buckets are per connection: a new client starts with a full
    // burst and repeats the exact same admit/reject pattern
    assert_eq!(run(&daemon), first, "second connection saw a different pattern");
}

#[test]
fn sigterm_drain_and_shutdown_op_flush_byte_identical_stores() {
    let dir_a = tmp_dir("drain-sigterm");
    let dir_b = tmp_dir("drain-shutdown");
    let workload = duplicate_heavy_workload();

    // daemon A: full workload, then SIGTERM
    let mut a = Daemon::start(&["--cache-dir", dir_a.to_str().unwrap()], false);
    a.run_client(&workload);
    let term = Command::new("kill")
        .args(["-TERM", &a.pid().to_string()])
        .status()
        .expect("send SIGTERM");
    assert!(term.success());
    a.wait_exit(Duration::from_secs(30));

    // daemon B: same workload, then the shutdown op
    let mut b = Daemon::start(&["--cache-dir", dir_b.to_str().unwrap()], false);
    b.run_client(&workload);
    let bye = b.run_client(&request(99, "shutdown", Json::Null));
    let bye = parse_line(bye.trim());
    assert_eq!(bye.get("ok").as_bool(), Some(true));
    assert_eq!(bye.get("body").get("draining").as_bool(), Some(true));
    b.wait_exit(Duration::from_secs(30));

    // both drains flushed the same acknowledged evaluations through
    // the same path: the stores must match file-for-file, byte-for-byte
    let files_a = store_files(&dir_a);
    let files_b = store_files(&dir_b);
    assert!(!files_a.is_empty(), "drained store must hold flushed shards");
    assert_eq!(
        files_a.keys().collect::<Vec<_>>(),
        files_b.keys().collect::<Vec<_>>(),
        "drain paths produced different store layouts"
    );
    for (name, bytes_a) in &files_a {
        assert_eq!(bytes_a, &files_b[name], "shard {name} differs between drain paths");
    }
}

#[test]
fn torn_and_oversized_requests_get_error_responses_daemon_survives() {
    let daemon = Daemon::start(&[], true);
    // arm the one-shot torn-request fault, then send a request that
    // the daemon will damage after framing: a 400 comes back (with the
    // id salvaged off the surviving prefix) and the connection lives on
    let mut text = request(
        1,
        "hook",
        Json::obj(vec![("kind", Json::from("torn_request"))]),
    );
    // id first and padding at the tail, so the surviving half of the
    // torn line still carries a salvageable id
    text.push_str(&format!("{{\"id\":2,\"op\":\"health\",\"zpad\":\"{}\"}}\n", "x".repeat(40)));
    text.push_str(&request(3, "health", Json::Null));
    let lines: Vec<Json> = daemon.run_client(&text).lines().map(parse_line).collect();
    assert_eq!(lines.len(), 3);
    assert_eq!(lines[0].get("ok").as_bool(), Some(true), "hook arm");
    assert_eq!(lines[1].get("ok").as_bool(), Some(false), "torn request must fail");
    assert_eq!(lines[1].get("code").as_usize(), Some(400));
    assert_eq!(lines[1].get("id").as_usize(), Some(2), "id salvaged from the torn line");
    assert_eq!(lines[2].get("ok").as_bool(), Some(true), "daemon keeps serving after");

    // an oversized line (> MAX_LINE) is a 413, and the connection
    // still serves the next request
    let mut text = format!(
        "{{\"id\":4,\"op\":\"health\",\"pad\":\"{}\"}}\n",
        "x".repeat(1 << 21)
    );
    text.push_str(&request(5, "health", Json::Null));
    let lines: Vec<Json> = daemon.run_client(&text).lines().map(parse_line).collect();
    assert_eq!(lines.len(), 2);
    assert_eq!(lines[0].get("ok").as_bool(), Some(false));
    assert_eq!(lines[0].get("code").as_usize(), Some(413));
    assert_eq!(lines[1].get("ok").as_bool(), Some(true));

    // non-UTF8 junk over a raw socket: error response, no panic
    let mut raw = std::net::TcpStream::connect(&daemon.addr).expect("raw connect");
    raw.write_all(&[0xFF, 0xFE, 0x80, b'\n']).expect("write junk");
    let mut resp = String::new();
    BufReader::new(raw.try_clone().expect("clone"))
        .read_line(&mut resp)
        .expect("read junk response");
    let j = parse_line(resp.trim());
    assert_eq!(j.get("ok").as_bool(), Some(false));
    assert_eq!(j.get("code").as_usize(), Some(400));
    drop(raw);

    // the daemon survived all of it
    let health = daemon.run_client(&request(9, "health", Json::Null));
    assert_eq!(parse_line(health.trim()).get("ok").as_bool(), Some(true));
}

#[test]
fn connection_thread_panic_is_joined_counted_and_daemon_survives() {
    // ISSUE 10 satellite: the accept loop used to drop finished
    // connection `JoinHandle`s via `retain(|h| !h.is_finished())`, so
    // a panicked connection thread vanished — payload, accounting and
    // all. Now every handle is joined and panics land in the
    // `connection_panics` counter while the daemon keeps serving.
    let daemon = Daemon::start(&[], true);
    let armed = daemon.run_client(&request(
        1,
        "hook",
        Json::obj(vec![("kind", Json::from("panic_connection"))]),
    ));
    assert_eq!(parse_line(armed.trim()).get("ok").as_bool(), Some(true));

    // the next request line trips the one-shot fault: its connection
    // thread panics before writing a response, so the socket sees EOF
    let mut raw = std::net::TcpStream::connect(&daemon.addr).expect("raw connect");
    raw.write_all(request(2, "health", Json::Null).as_bytes()).expect("write request");
    let mut resp = String::new();
    let n = BufReader::new(raw.try_clone().expect("clone"))
        .read_line(&mut resp)
        .expect("read from killed connection");
    assert_eq!(n, 0, "the panicking connection must die responseless, got {resp:?}");
    drop(raw);

    // the accept loop reaps the dead thread on its idle poll tick and
    // counts the panic; poll the daemon's own stats until it lands
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let stats = daemon.run_client(&request(3, "stats", Json::Null));
        let body = parse_line(stats.trim());
        let panics = body.get("body").get("connection_panics").as_usize().unwrap_or(0);
        if panics == 1 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "connection_panics never reached 1 (last saw {panics})"
        );
        std::thread::sleep(Duration::from_millis(25));
    }

    // one dead connection thread, zero collateral damage
    let health = daemon.run_client(&request(4, "health", Json::Null));
    assert_eq!(parse_line(health.trim()).get("ok").as_bool(), Some(true));
}

#[test]
fn serve_cli_rejects_the_blackhole_quota_config() {
    // ISSUE 10 satellite: the token bucket caps refill at `burst`, so
    // `--quota-burst 0` with a positive `--quota-rate` admits nothing,
    // ever — a daemon that only answers 429s. The CLI must refuse to
    // boot it instead of silently blackholing every client.
    let out = Command::new(env!("CARGO_BIN_EXE_fso"))
        .args([
            "serve", "--listen", "127.0.0.1:0", "--quota-burst", "0", "--quota-rate", "5",
        ])
        .stdin(Stdio::null())
        .output()
        .expect("run fso serve");
    assert!(!out.status.success(), "blackhole quota config must be rejected: {out:?}");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(
        err.contains("admits no requests"),
        "rejection must explain the blackhole: {err}"
    );
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fso-serve-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Every file under a store directory (recursive), keyed by relative
/// path — minus the `.store.lock` files, whose content is the owning
/// process id and legitimately differs.
fn store_files(dir: &PathBuf) -> std::collections::BTreeMap<String, Vec<u8>> {
    fn walk(
        root: &std::path::Path,
        dir: &std::path::Path,
        out: &mut std::collections::BTreeMap<String, Vec<u8>>,
    ) {
        let Ok(rd) = std::fs::read_dir(dir) else { return };
        for entry in rd.flatten() {
            let path = entry.path();
            if path.is_dir() {
                walk(root, &path, out);
            } else {
                let rel = path.strip_prefix(root).unwrap().to_string_lossy().into_owned();
                if rel.ends_with(".store.lock") || rel.ends_with(".lock") {
                    continue;
                }
                out.insert(rel, std::fs::read(&path).expect("read store file"));
            }
        }
    }
    let mut out = std::collections::BTreeMap::new();
    walk(dir, dir, &mut out);
    out
}
