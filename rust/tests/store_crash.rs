//! Crash-injection tests for the shared store flush path (ISSUE 4
//! satellite): arm a one-shot fault hook, let the flush die at a
//! protocol step, then reopen the directory with a fresh store (the
//! moral equivalent of a fresh process) and prove that
//!
//!   * records acknowledged by a *completed* flush are never lost,
//!   * a torn / un-renamed temp file is never served,
//!   * the abandoned `.store.lock` is stolen once stale, so the store
//!     never wedges.
//!
//! The fault hook is process-global, so these tests serialize through
//! a local mutex, and the lock staleness window is shrunk via
//! `FSO_STORE_LOCK_STALE_MS` so recovery takes milliseconds.

use std::fs;
use std::path::PathBuf;
use std::sync::Mutex;

use fso::coordinator::store::fault::{self, FlushFault};
use fso::coordinator::store::sidecar::idx_path;
use fso::coordinator::{Codec, ModelStore};
use fso::util::json::Json;

static SERIAL: Mutex<()> = Mutex::new(());

fn setup(tag: &str) -> (std::sync::MutexGuard<'static, ()>, PathBuf) {
    let guard = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    // must be set before the process's first DirLock acquire (read once)
    std::env::set_var("FSO_STORE_LOCK_STALE_MS", "200");
    fault::disarm();
    let dir = std::env::temp_dir()
        .join(format!("fso-store-crash-{}-{tag}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    (guard, dir)
}

fn payload(v: f64) -> Json {
    Json::obj(vec![("w", Json::arr_f64(&[v, 2.0 * v])), ("b", v.into())])
}

/// Keys sharing one shard (top byte 0x0a -> shard 2 of the 8-shard
/// model-store default), so a single flush writes a single file.
fn key(i: u64) -> u64 {
    0x0a00_0000_0000_0000 | i
}

fn lock_file(dir: &PathBuf) -> PathBuf {
    dir.join(".store.lock")
}

fn tmp_files(dir: &PathBuf) -> Vec<String> {
    fs::read_dir(dir)
        .map(|rd| {
            rd.flatten()
                .map(|e| e.file_name().to_string_lossy().into_owned())
                .filter(|n| n.contains(".tmp-"))
                .collect()
        })
        .unwrap_or_default()
}

#[test]
fn crash_between_temp_write_and_rename_loses_no_acknowledged_record() {
    let (_guard, dir) = setup("before-rename");
    {
        let store = ModelStore::open(&dir).unwrap();
        for i in 0..4 {
            store.put("f", key(i), payload(i as f64));
        }
        store.flush().unwrap(); // acknowledged: must survive anything
    }
    let store = ModelStore::open(&dir).unwrap();
    store.put("f", key(9), payload(9.0));
    fault::arm(FlushFault::BeforeRename);
    let err = store.flush();
    assert!(err.is_err(), "armed flush must report the injected crash");
    assert!(
        lock_file(&dir).exists(),
        "a crash mid-flush leaves the directory lock behind"
    );
    assert!(
        !tmp_files(&dir).is_empty(),
        "the staged temp file must exist (written, never renamed)"
    );
    // the "process" died: never let its Drop-flush run
    std::mem::forget(store);

    // fresh store = fresh process: acknowledged records intact, the
    // unacknowledged one lost (it was never durable), nothing torn
    let store = ModelStore::open(&dir).unwrap();
    for i in 0..4 {
        assert_eq!(
            store.get("f", key(i)),
            Some(payload(i as f64)),
            "acknowledged record {i} lost after injected crash"
        );
    }
    assert_eq!(
        store.get("f", key(9)),
        None,
        "the un-renamed record was never acknowledged and must read as a miss"
    );
    // recovery flush steals the stale lock (200 ms window) and succeeds
    store.put("f", key(9), payload(9.0));
    store.flush().unwrap();
    assert!(
        !lock_file(&dir).exists(),
        "recovered flush must release the (stolen) lock"
    );
    // compaction sweeps the orphaned temp file
    store.compact().unwrap();
    assert!(
        tmp_files(&dir).is_empty(),
        "compaction must sweep orphaned temp files: {:?}",
        tmp_files(&dir)
    );
    drop(store);
    let store = ModelStore::open(&dir).unwrap();
    assert_eq!(store.get("f", key(9)), Some(payload(9.0)));
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn crash_between_rename_and_lock_release_keeps_everything_durable() {
    let (_guard, dir) = setup("before-release");
    {
        let store = ModelStore::open(&dir).unwrap();
        store.put("f", key(1), payload(1.0));
        store.flush().unwrap();
    }
    let store = ModelStore::open(&dir).unwrap();
    store.put("f", key(2), payload(2.0));
    fault::arm(FlushFault::BeforeLockRelease);
    assert!(store.flush().is_err(), "armed flush must report the injected crash");
    assert!(
        lock_file(&dir).exists(),
        "the crash happened while holding the directory lock"
    );
    std::mem::forget(store);

    let store = ModelStore::open(&dir).unwrap();
    assert_eq!(store.get("f", key(1)), Some(payload(1.0)));
    assert_eq!(
        store.get("f", key(2)),
        Some(payload(2.0)),
        "the rename completed before the crash, so the record is durable"
    );
    // the next flush must steal the stale lock instead of wedging
    store.put("f", key(3), payload(3.0));
    store.flush().unwrap();
    assert!(!lock_file(&dir).exists(), "stale lock stolen and released");
    drop(store);
    let store = ModelStore::open(&dir).unwrap();
    for i in 1..=3 {
        assert_eq!(store.get("f", key(i)), Some(payload(i as f64)));
    }
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn torn_tail_line_is_never_served_and_repairs_on_rewrite() {
    let (_guard, dir) = setup("torn-tail");
    let shard_file = dir.join("model-002.jsonl");
    {
        // v1 JSONL codec: the tear below slices a text line in half
        let store = ModelStore::open(&dir).unwrap().with_codec(Codec::V1Jsonl);
        store.put("f", key(1), payload(1.0));
        store.put("f", key(2), payload(2.0));
        store.flush().unwrap();
    }
    // tear the file mid-way through its last line (what a non-atomic
    // writer or a truncated disk would leave behind)
    let text = fs::read_to_string(&shard_file).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 2);
    let torn = format!("{}\n{}", lines[0], &lines[1][..lines[1].len() / 2]);
    fs::write(&shard_file, torn).unwrap();

    let store = ModelStore::open(&dir).unwrap();
    // sorted (kind, key) order puts key(1) on the intact first line
    assert_eq!(
        store.get("f", key(1)),
        Some(payload(1.0)),
        "intact line must still load"
    );
    assert_eq!(
        store.get("f", key(2)),
        None,
        "the torn record must read as a miss, never as garbage"
    );
    // repopulating and flushing rewrites the shard cleanly
    store.put("f", key(2), payload(2.0));
    store.flush().unwrap();
    drop(store);
    let store = ModelStore::open(&dir).unwrap();
    assert_eq!(store.get("f", key(1)), Some(payload(1.0)));
    assert_eq!(store.get("f", key(2)), Some(payload(2.0)));
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn crash_before_sidecar_rename_rebuilds_the_index_silently() {
    // ISSUE 7 satellite: the flush protocol renames the shard body
    // *before* staging its `.idx` sidecar, so a crash in the gap leaves
    // every record durable with only the disposable index missing —
    // readers must fall back to the streaming scan and rebuild it
    // without ever surfacing an error
    let (_guard, dir) = setup("idx-crash");
    {
        let store = ModelStore::open(&dir).unwrap();
        for i in 0..3 {
            store.put("f", key(i), payload(i as f64));
        }
        fault::arm(FlushFault::IdxBeforeRename);
        assert!(store.flush().is_err(), "armed flush must report the injected crash");
        assert!(
            lock_file(&dir).exists(),
            "the crash happened while holding the directory lock"
        );
        std::mem::forget(store);
    }
    let shard = dir.join("model-002.fsb");
    assert!(shard.exists(), "the shard rename completed before the idx crash");
    assert!(
        !idx_path(&shard).exists(),
        "the sidecar was staged but never renamed"
    );
    assert!(
        !tmp_files(&dir).is_empty(),
        "the staged idx temp file must be left behind"
    );

    // fresh process: every acknowledged record is durable, the missing
    // sidecar falls back to the scan and is rebuilt best-effort
    let store = ModelStore::open(&dir).unwrap();
    for i in 0..3 {
        assert_eq!(
            store.get("f", key(i)),
            Some(payload(i as f64)),
            "record {i} lost to a sidecar-only crash"
        );
    }
    assert!(
        store.sidecar_rebuilds() >= 1,
        "the missing sidecar must be rebuilt silently"
    );
    assert!(idx_path(&shard).exists(), "rebuild rewrites the sidecar file");
    // the next flush steals the stale lock and sweeps nothing it needs
    store.put("f", key(9), payload(9.0));
    store.flush().unwrap();
    assert!(!lock_file(&dir).exists(), "stale lock stolen and released");
    assert!(idx_path(&shard).exists(), "flush rewrites a fresh sidecar");
    store.compact().unwrap();
    assert!(
        tmp_files(&dir).is_empty(),
        "compaction must sweep the orphaned idx temp: {:?}",
        tmp_files(&dir)
    );
    drop(store);
    let store = ModelStore::open(&dir).unwrap();
    for i in 0..3 {
        assert_eq!(store.get("f", key(i)), Some(payload(i as f64)));
    }
    assert_eq!(store.get("f", key(9)), Some(payload(9.0)));
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn double_crash_then_recovery_converges() {
    // two successive injected crashes (one per fault point) must still
    // leave a store that recovers to full consistency
    let (_guard, dir) = setup("double");
    {
        let store = ModelStore::open(&dir).unwrap();
        store.put("f", key(1), payload(1.0));
        store.flush().unwrap();
    }
    {
        let store = ModelStore::open(&dir).unwrap();
        store.put("f", key(2), payload(2.0));
        fault::arm(FlushFault::BeforeRename);
        assert!(store.flush().is_err());
        std::mem::forget(store);
    }
    {
        let store = ModelStore::open(&dir).unwrap();
        store.put("f", key(3), payload(3.0));
        fault::arm(FlushFault::BeforeLockRelease);
        assert!(store.flush().is_err());
        std::mem::forget(store);
    }
    let store = ModelStore::open(&dir).unwrap();
    assert_eq!(store.get("f", key(1)), Some(payload(1.0)), "acknowledged survives");
    assert_eq!(store.get("f", key(3)), Some(payload(3.0)), "renamed-before-crash survives");
    store.put("f", key(2), payload(2.0));
    store.flush().unwrap();
    assert!(!lock_file(&dir).exists());
    drop(store);
    let store = ModelStore::open(&dir).unwrap();
    for i in 1..=3 {
        assert_eq!(store.get("f", key(i)), Some(payload(i as f64)));
    }
    let _ = fs::remove_dir_all(&dir);
}
