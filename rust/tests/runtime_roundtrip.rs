//! Integration test: the rust PJRT runtime must reproduce, bit-for-bit
//! (to f32 tolerance), the outputs python recorded for the AOT artifacts.
//! This is the contract that lets python leave the request path.

use fso::runtime::{load_fixture, Engine};
use fso::util::tensor::Tensor;

fn engine() -> Option<Engine> {
    let dir = fso::test_support::artifacts_dir()?;
    Some(Engine::load(&dir).expect("engine load"))
}

fn assert_close(got: &Tensor, want: &Tensor, tol: f32, what: &str) {
    assert_eq!(got.shape(), want.shape(), "{what}: shape");
    let d = got.max_abs_diff(want);
    assert!(d <= tol, "{what}: max abs diff {d} > {tol}");
}

#[test]
fn ann_predict_matches_python_golden() {
    let Some(eng) = engine() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let dir = eng.manifest.dir.clone();
    let theta = load_fixture(&dir, "ann_theta").unwrap();
    let x = load_fixture(&dir, "ann_x").unwrap();
    let want = load_fixture(&dir, "ann_pred").unwrap();
    let out = eng.run_checked("ann32x4_relu", "predict", &[theta, x]).unwrap();
    assert_eq!(out.len(), 1);
    assert_close(&out[0], &want, 1e-4, "ann predict");
}

#[test]
fn ann_train_step_matches_python_golden() {
    let Some(eng) = engine() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let dir = eng.manifest.dir.clone();
    let theta = load_fixture(&dir, "ann_theta").unwrap();
    let x = load_fixture(&dir, "ann_x").unwrap();
    let y = load_fixture(&dir, "ann_y").unwrap();
    let w = load_fixture(&dir, "ann_w").unwrap();
    let p = theta.len();
    let m = Tensor::zeros(&[p]);
    let v = Tensor::zeros(&[p]);
    let t = Tensor::scalar(1.0);
    let lr = Tensor::scalar(1e-3);
    let out = eng
        .run_checked("ann32x4_relu", "train_step", &[theta, m, v, t, lr, x, y, w])
        .unwrap();
    assert_eq!(out.len(), 4);
    assert_close(&out[0], &load_fixture(&dir, "ann_theta2").unwrap(), 1e-5, "theta'");
    assert_close(&out[1], &load_fixture(&dir, "ann_m2").unwrap(), 1e-5, "m'");
    assert_close(&out[2], &load_fixture(&dir, "ann_v2").unwrap(), 1e-6, "v'");
    assert_close(&out[3], &load_fixture(&dir, "ann_loss").unwrap().reshaped_scalar(), 1e-5, "loss");
}

#[test]
fn gcn_predict_and_embed_match_python_golden() {
    let Some(eng) = engine() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let dir = eng.manifest.dir.clone();
    let theta = load_fixture(&dir, "gcn_theta").unwrap();
    let nodes = load_fixture(&dir, "gcn_nodes").unwrap();
    let adj = load_fixture(&dir, "gcn_adj").unwrap();
    let mask = load_fixture(&dir, "gcn_mask").unwrap();
    let gfeat = load_fixture(&dir, "gcn_gfeat").unwrap();

    let out = eng
        .run_checked(
            "gcn3",
            "predict",
            &[theta.clone(), nodes.clone(), adj.clone(), mask.clone(), gfeat],
        )
        .unwrap();
    assert_close(&out[0], &load_fixture(&dir, "gcn_pred").unwrap(), 1e-3, "gcn predict");

    let emb = eng.run_checked("gcn3", "embed", &[theta, nodes, adj, mask]).unwrap();
    assert_close(&emb[0], &load_fixture(&dir, "gcn_emb").unwrap(), 1e-3, "gcn embed");
}

#[test]
fn gcn_train_step_matches_python_golden() {
    let Some(eng) = engine() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let dir = eng.manifest.dir.clone();
    let theta = load_fixture(&dir, "gcn_theta").unwrap();
    let nodes = load_fixture(&dir, "gcn_nodes").unwrap();
    let adj = load_fixture(&dir, "gcn_adj").unwrap();
    let mask = load_fixture(&dir, "gcn_mask").unwrap();
    let gfeat = load_fixture(&dir, "gcn_gfeat").unwrap();
    let y = load_fixture(&dir, "gcn_y").unwrap();
    let p = theta.len();
    let w = Tensor::from_vec(&[32], vec![1.0; 32]).unwrap();
    let out = eng
        .run_checked(
            "gcn3",
            "train_step",
            &[
                theta,
                Tensor::zeros(&[p]),
                Tensor::zeros(&[p]),
                Tensor::scalar(1.0),
                Tensor::scalar(1e-3),
                nodes,
                adj,
                mask,
                gfeat,
                y,
                w,
            ],
        )
        .unwrap();
    assert_close(&out[0], &load_fixture(&dir, "gcn_theta2").unwrap(), 1e-4, "gcn theta'");
    assert_close(&out[3], &load_fixture(&dir, "gcn_loss").unwrap().reshaped_scalar(), 1e-4, "gcn loss");
}

#[test]
fn executable_cache_compiles_once() {
    let Some(eng) = engine() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let dir = eng.manifest.dir.clone();
    let theta = load_fixture(&dir, "ann_theta").unwrap();
    let x = load_fixture(&dir, "ann_x").unwrap();
    for _ in 0..3 {
        eng.run_checked("ann32x4_relu", "predict", &[theta.clone(), x.clone()]).unwrap();
    }
    let st = eng.stats();
    assert_eq!(st.compiles, 1, "must compile once, cache after");
    assert_eq!(st.executions, 3);
}

#[test]
fn run_checked_rejects_bad_shapes() {
    let Some(eng) = engine() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let bad = Tensor::zeros(&[3]);
    let x = Tensor::zeros(&[32, 16]);
    assert!(eng.run_checked("ann32x4_relu", "predict", &[bad, x]).is_err());
    assert!(eng.run_checked("ann32x4_relu", "nope", &[]).is_err());
    assert!(eng.run_checked("missing_variant", "predict", &[]).is_err());
}

/// Helper: fixtures store scalars as [1] arrays; train_step outputs them
/// as rank-0.
trait ReshapedScalar {
    fn reshaped_scalar(self) -> Tensor;
}
impl ReshapedScalar for Tensor {
    fn reshaped_scalar(self) -> Tensor {
        Tensor::from_vec(&[], self.into_vec()).unwrap()
    }
}
