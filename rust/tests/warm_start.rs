//! Warm-start determinism (ISSUE 2): a run that reads a populated
//! persistent cache store must produce byte-identical datagen rows and
//! DSE Pareto fronts to the cold run that populated it — while
//! reporting >0 disk hits and strictly fewer oracle evaluations.

use std::path::PathBuf;
use std::sync::Arc;

use fso::backend::{BackendConfig, Enablement};
use fso::coordinator::dse_driver::{axiline_svm_problem, DseDriver, DseOutcome};
use fso::coordinator::{
    datagen, CacheStore, DatagenConfig, EvalService, EvalStats, GeneratedData, ModelStore,
};
use fso::dse::MotpeConfig;
use fso::generators::{ArchConfig, Platform};
use fso::workloads::{NonDnnAlgo, NonDnnWorkload, WorkloadSpec};

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("fso-warmstart-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

// mirrors tests/e2e_small.rs, whose parameters are known to yield a
// non-empty feasible front and Eq.-3 winners
fn small_cfg() -> DatagenConfig {
    DatagenConfig {
        n_arch: 6,
        n_backend_train: 10,
        n_backend_test: 4,
        ..DatagenConfig::small(Platform::Axiline, Enablement::Gf12)
    }
}

fn run_datagen(store: &Arc<CacheStore>, cfg: &DatagenConfig) -> GeneratedData {
    let service = EvalService::new(cfg.enablement, cfg.seed)
        .with_workers(2)
        .with_cache_store(Arc::clone(store));
    datagen::generate_with(&service, cfg).expect("datagen")
}

#[test]
fn warm_start_datagen_rows_are_byte_identical_with_disk_hits() {
    let dir = tmp_dir("datagen");
    let cfg = small_cfg();

    let cold = {
        let store = Arc::new(CacheStore::open(&dir).unwrap());
        let g = run_datagen(&store, &cfg);
        assert_eq!(g.stats.disk_hits, 0, "cold run must not see disk hits");
        assert!(g.stats.oracle_misses > 0, "cold run must run the oracle");
        assert!(store.flush().unwrap() > 0, "cold run must flush shards");
        g
    };

    // fresh store instance + fresh service: everything re-read from disk
    let warm = {
        let store = Arc::new(CacheStore::open(&dir).unwrap());
        run_datagen(&store, &cfg)
    };

    assert_eq!(cold.dataset.rows, warm.dataset.rows);
    assert_eq!(cold.backend_split.train, warm.backend_split.train);
    assert_eq!(cold.backend_split.test, warm.backend_split.test);
    assert!(warm.stats.disk_hits > 0, "warm run saw no disk hits: {}", warm.stats);
    assert_eq!(
        warm.stats.oracle_misses, 0,
        "warm run re-ran the oracle: {}",
        warm.stats
    );
    assert!(warm.stats.oracle_misses < cold.stats.oracle_misses);
    // storage engine v2: warm point lookups are answered by the `.idx`
    // sidecars frame-by-frame — no shard is ever scanned wholesale
    assert!(warm.stats.sidecar_hits > 0, "no sidecar hits: {}", warm.stats);
    assert_eq!(warm.stats.shard_loads, 0, "warm run scanned a shard: {}", warm.stats);

    // byte-for-byte: the CSVs the CLI would write are identical
    let cold_csv = tmp_dir("datagen-cold-csv").with_extension("csv");
    let warm_csv = tmp_dir("datagen-warm-csv").with_extension("csv");
    cold.dataset.write_csv(&cold_csv).unwrap();
    warm.dataset.write_csv(&warm_csv).unwrap();
    assert_eq!(
        std::fs::read(&cold_csv).unwrap(),
        std::fs::read(&warm_csv).unwrap(),
        "cold and warm CSVs differ"
    );
    let _ = std::fs::remove_file(&cold_csv);
    let _ = std::fs::remove_file(&warm_csv);
    let _ = std::fs::remove_dir_all(&dir);
}

fn run_dse(
    g: &GeneratedData,
    store: &Arc<CacheStore>,
    mstore: &Arc<ModelStore>,
) -> (DseOutcome, EvalStats, bool) {
    let mut service = EvalService::new(Enablement::Gf12, 2023)
        .with_workers(2)
        .with_cache_store(Arc::clone(store))
        .with_model_store(Arc::clone(mstore));
    // read-through surrogate fit (ISSUE 3): the cold run fits and
    // writes behind; the warm run replays the stored bundle
    let replayed = service.fit_surrogate(&g.dataset, &g.backend_split, 1).unwrap();
    let driver = DseDriver { service };
    let mut runtimes: Vec<f64> = g.dataset.rows.iter().map(|r| r.runtime_s).collect();
    runtimes.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let problem = axiline_svm_problem(
        g.dataset.rows.iter().map(|r| r.power_w).fold(0.0, f64::max) * 2.0,
        runtimes[runtimes.len() * 3 / 4],
    );
    let outcome = driver
        .run_batched(
            &problem,
            60,
            2,
            MotpeConfig { n_startup: 16, seed: 5, ..Default::default() },
            12,
        )
        .unwrap();
    let stats = driver.stats();
    driver.service.flush_cache().unwrap();
    (outcome, stats, replayed)
}

#[test]
fn warm_start_dse_pareto_front_is_identical_with_disk_hits_and_zero_refits() {
    let dir = tmp_dir("dse");
    // shared surrogate input (plain datagen — the caches under test
    // cover the DSE driver's oracle traffic and the fitted surrogate)
    let g = datagen::generate(&small_cfg()).unwrap();

    let (cold, cold_stats, cold_replayed) = {
        let store = Arc::new(CacheStore::open(&dir).unwrap());
        let mstore = Arc::new(ModelStore::open_under(&dir).unwrap());
        run_dse(&g, &store, &mstore)
    };
    let store = Arc::new(CacheStore::open(&dir).unwrap());
    let mstore = Arc::new(ModelStore::open_under(&dir).unwrap());
    let (warm, warm_stats, warm_replayed) = run_dse(&g, &store, &mstore);

    assert!(
        !cold.best.is_empty(),
        "Eq. 3 selected no winners — the cache never saw oracle traffic"
    );
    assert_eq!(cold.points, warm.points, "MOTPE trajectories diverged");
    assert_eq!(cold.best, warm.best, "Eq. 3 winners diverged");
    assert_eq!(cold.ground_truth_errors, warm.ground_truth_errors);
    assert_eq!(cold.pareto_front(), warm.pareto_front(), "Pareto fronts diverged");

    assert!(cold_stats.oracle_misses > 0);
    assert_eq!(cold_stats.disk_hits, 0);
    assert!(warm_stats.disk_hits > 0, "warm DSE saw no disk hits: {warm_stats}");
    assert_eq!(
        warm_stats.oracle_misses, 0,
        "warm DSE re-ran the oracle: {warm_stats}"
    );
    // ISSUE 3 acceptance: the warm run performs 0 surrogate refits —
    // the trajectory identity above proves the stored bundle replays
    // bit-identical predictions
    assert!(!cold_replayed, "cold run must fit the surrogate fresh");
    assert!(warm_replayed, "warm run must replay the stored surrogate");
    assert!(warm_stats.model_hits > 0, "warm run must hit the model store");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_start_survives_forced_compaction_byte_identically() {
    // ISSUE 4 acceptance: an `fso store compact` between the cold and
    // warm runs must not change any read result — the warm rerun still
    // replays byte-identical rows with 0 oracle re-runs.
    let dir = tmp_dir("compact");
    let cfg = small_cfg();

    let cold = {
        let store = Arc::new(CacheStore::open(&dir).unwrap());
        let g = run_datagen(&store, &cfg);
        assert!(store.flush().unwrap() > 0);
        g
    };

    // forced compaction (what the CLI runs for `fso store compact`)
    {
        let store = CacheStore::open(&dir).unwrap();
        let rep = store.compact().unwrap();
        assert!(rep.live_records > 0, "compaction must keep the live records");
        // a second compact is a no-op: nothing left to reclaim
        let rep2 = store.compact().unwrap();
        assert_eq!(rep2.shards_rewritten, 0, "second compact must be a no-op: {rep2}");
        assert_eq!(rep2.bytes_before, rep2.bytes_after);
    }

    let warm = {
        let store = Arc::new(CacheStore::open(&dir).unwrap());
        run_datagen(&store, &cfg)
    };
    assert_eq!(cold.dataset.rows, warm.dataset.rows, "compaction changed a read");
    assert_eq!(
        warm.stats.oracle_misses, 0,
        "warm run after compact re-ran the oracle: {}",
        warm.stats
    );
    assert!(warm.stats.disk_hits > 0, "no disk hits after compact: {}", warm.stats);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn multi_enablement_sweep_warm_starts_from_one_store() {
    let dir = tmp_dir("sweep");
    let mk = |e: Enablement| DatagenConfig {
        n_arch: 3,
        n_backend_train: 5,
        n_backend_test: 2,
        ..DatagenConfig::small(Platform::Vta, e)
    };
    let cfgs = [mk(Enablement::Gf12), mk(Enablement::Ng45)];

    let cold = {
        let store = Arc::new(CacheStore::open(&dir).unwrap());
        let out = datagen::generate_sweep(&cfgs, Some(Arc::clone(&store))).unwrap();
        store.flush().unwrap();
        out
    };
    let store = Arc::new(CacheStore::open(&dir).unwrap());
    let warm = datagen::generate_sweep(&cfgs, Some(Arc::clone(&store))).unwrap();

    for ((cfg, c), w) in cfgs.iter().zip(&cold).zip(&warm) {
        let tag = cfg.enablement.name();
        assert_eq!(c.dataset.rows, w.dataset.rows, "[{tag}] rows diverged");
        assert!(w.stats.disk_hits > 0, "[{tag}] no disk hits: {}", w.stats);
        assert_eq!(w.stats.oracle_misses, 0, "[{tag}] oracle re-ran: {}", w.stats);
    }
    // the two enablements really produced different data (no key mixup)
    assert_ne!(cold[0].dataset.rows, cold[1].dataset.rows);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn storage_engine_counters_pin_the_lazy_and_sidecar_paths() {
    // ISSUE 7 satellite: the streaming/sidecar counters are part of the
    // warm-start contract — point lookups (hits *and* misses) decode at
    // most the one frame they return, and a full shard load defers
    // every body it does not need.
    let dir = tmp_dir("engine-counters");
    let p = Platform::Axiline;
    let arch = ArchConfig::new(
        p,
        p.param_space().iter().map(|s| s.kind.from_unit(0.5)).collect(),
    );
    let ev = EvalService::new(Enablement::Gf12, 7)
        .evaluate(&arch, BackendConfig::new(0.8, 0.5), None)
        .unwrap();
    // 30 records spread over the 16 shards (top byte routes)
    let keys: Vec<u64> = (0..30u64).map(|i| (i << 56) | i).collect();
    {
        let store = CacheStore::open(&dir).unwrap();
        for &k in &keys {
            store.put_eval(k, ev);
        }
        store.flush().unwrap();
    }

    let store = CacheStore::open(&dir).unwrap();
    // present keys: one sidecar frame fetch + one decode each, no scans
    for &k in &keys[..3] {
        assert!(store.get_eval(k).is_some(), "flushed record lost");
    }
    assert_eq!(store.sidecar_hits(), 3, "present lookups go through the sidecar");
    assert_eq!(store.full_decodes(), 3, "exactly the returned frames decode");
    assert_eq!(store.shard_loads(), 0, "point lookups must not scan shards");
    // absent keys land in populated shards: definitive sidecar misses,
    // zero additional record parses (the warm-start miss-path pin)
    for i in 0..5u64 {
        assert!(store.get_eval(0x0900_0000_0000_1000 | i).is_none());
    }
    assert_eq!(store.sidecar_hits(), 8, "misses are answered by the sidecar too");
    assert_eq!(store.full_decodes(), 3, "a lookup miss must parse no record at all");
    assert_eq!(store.shard_loads(), 0);
    assert_eq!(store.sidecar_rebuilds(), 0, "fresh sidecars never rebuild");

    // a full load streams envelopes and defers every unread body
    store.load_all();
    assert!(store.shard_loads() > 0);
    assert!(
        store.lazy_skips() >= 27,
        "full load must defer the unread bodies: {} lazy skips",
        store.lazy_skips()
    );
    assert_eq!(store.full_decodes(), 3, "load_all must not decode eagerly");
    assert_eq!(store.transcoded_records(), 0, "single-codec dir never transcodes");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn flow_results_are_shared_across_workloads_through_disk() {
    // the workload-free flow key lets a *different* workload reuse the
    // expensive SP&R result from disk; only the simulator re-runs
    let dir = tmp_dir("flowshare");
    let p = Platform::Axiline;
    let arch = ArchConfig::new(
        p,
        p.param_space().iter().map(|s| s.kind.from_unit(0.5)).collect(),
    );
    let bcfg = BackendConfig::new(0.8, 0.5);

    let cold_flow = {
        let store = Arc::new(CacheStore::open(&dir).unwrap());
        let svc = EvalService::new(Enablement::Gf12, 7).with_cache_store(Arc::clone(&store));
        let ev = svc.evaluate(&arch, bcfg, None).unwrap();
        store.flush().unwrap();
        ev.flow
    };

    let store = Arc::new(CacheStore::open(&dir).unwrap());
    let svc = EvalService::new(Enablement::Gf12, 7).with_cache_store(store);
    let wl = WorkloadSpec::NonDnn(NonDnnWorkload::standard(NonDnnAlgo::Svm, 55));
    let ev = svc.evaluate(&arch, bcfg, Some(&wl)).unwrap();
    let s = svc.stats();
    assert_eq!(ev.flow.backend, cold_flow.backend, "flow PPA must match the cold run");
    assert_eq!(ev.flow.synth, cold_flow.synth);
    assert_eq!(s.disk_hits, 1, "flow should load from disk: {s}");
    assert_eq!(
        s.oracle_misses, 1,
        "the new workload's simulator pass is a (cheap) miss: {s}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
