//! Integration: datagen -> two-stage training -> prediction -> DSE on
//! small sizes, plus the predict server under concurrent clients.

use fso::backend::Enablement;
use fso::coordinator::dse_driver::{axiline_svm_problem, DseDriver, SurrogateBundle};
use fso::coordinator::{datagen, DatagenConfig, ModelMenu, PredictServer, TrainOptions, Trainer};
use fso::data::Metric;
use fso::dse::MotpeConfig;
use fso::generators::Platform;

fn small_dataset(platform: Platform) -> fso::coordinator::GeneratedData {
    let mut cfg = DatagenConfig::small(platform, Enablement::Gf12);
    cfg.n_arch = 8;
    cfg.n_backend_train = 12;
    cfg.n_backend_test = 4;
    datagen::generate(&cfg).expect("datagen")
}

#[test]
fn trees_pipeline_all_platforms() {
    for platform in Platform::ALL {
        let g = small_dataset(platform);
        let trainer = Trainer::new(None);
        let opts = TrainOptions {
            menu: ModelMenu::trees_only(),
            ..Default::default()
        };
        let report = trainer
            .run(&g.dataset, &g.backend_split, Metric::Power, &opts)
            .expect("train");
        let gbdt = &report.models["GBDT"];
        assert!(
            gbdt.mu_ape < 25.0,
            "{platform}: GBDT muAPE {:.1}% way off",
            gbdt.mu_ape
        );
        assert!(report.roi.accuracy > 0.7, "{platform}: ROI acc {}", report.roi.accuracy);
    }
}

#[test]
fn surrogate_bundle_predicts_all_metrics() {
    let g = small_dataset(Platform::Vta);
    let s = SurrogateBundle::fit(&g.dataset, &g.backend_split, 1).unwrap();
    let (in_roi, pred) = s.predict(&g.dataset.rows[0].features_vec());
    let _ = in_roi;
    for m in Metric::ALL {
        assert!(pred[&m].is_finite());
        assert!(pred[&m] > 0.0, "{m}: {}", pred[&m]);
    }
}

#[test]
fn dse_end_to_end_small() {
    let g = small_dataset(Platform::Axiline);
    let surrogate = SurrogateBundle::fit(&g.dataset, &g.backend_split, 1).unwrap();
    let driver = DseDriver::new(Enablement::Gf12, surrogate, 2023);
    let mut runtimes: Vec<f64> = g.dataset.rows.iter().map(|r| r.runtime_s).collect();
    runtimes.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let problem = axiline_svm_problem(
        g.dataset.rows.iter().map(|r| r.power_w).fold(0.0, f64::max) * 2.0,
        runtimes[runtimes.len() * 3 / 4],
    );
    let outcome = driver
        .run(&problem, 80, 2, MotpeConfig { n_startup: 16, ..Default::default() })
        .unwrap();
    assert_eq!(outcome.points.len(), 80);
    assert!(!outcome.best.is_empty(), "no feasible winner found");
    for errs in &outcome.ground_truth_errors {
        for m in Metric::ALL {
            assert!(errs[&m].is_finite());
            assert!(errs[&m] < 1.0, "{m} error {:.2} out of band", errs[&m]);
        }
    }
}

#[test]
fn predict_server_concurrent_clients() {
    let Some(artifacts) = fso::test_support::artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let server = PredictServer::start(artifacts.clone()).unwrap();
    let engine = fso::runtime::Engine::load(&artifacts).unwrap();
    let variant = engine.manifest.variant("ann16x3_relu").unwrap().clone();
    let theta: Vec<f32> =
        fso::models::ann::glorot_init(&variant, &mut fso::util::rng::Rng::new(3))
            .data()
            .to_vec();
    let feat = engine.manifest.feat;

    std::thread::scope(|scope| {
        for c in 0..6 {
            let client = server.client();
            let theta = theta.clone();
            scope.spawn(move || {
                let mut rng = fso::util::rng::Rng::new(c);
                let rows: Vec<Vec<f32>> =
                    (0..50).map(|_| (0..feat).map(|_| rng.f32()).collect()).collect();
                let out = client.predict("ann16x3_relu", &theta, rows.clone()).unwrap();
                assert_eq!(out.len(), 50);
                // same rows again must give identical answers (stateless)
                let out2 = client.predict("ann16x3_relu", &theta, rows).unwrap();
                assert_eq!(out, out2);
            });
        }
    });
    let stats = server.stats().unwrap();
    assert_eq!(stats.rows, 6 * 50 * 2);
    assert!(stats.batches >= stats.rows / 32);
}

#[test]
fn ann_gcn_learn_on_real_data() {
    let Some(artifacts) = fso::test_support::artifacts_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let g = small_dataset(Platform::Axiline);
    let engine = std::rc::Rc::new(fso::runtime::Engine::load(&artifacts).unwrap());
    let trainer = Trainer::new(Some(engine));
    let opts = TrainOptions {
        menu: ModelMenu { gbdt: false, rf: false, ann: true, ensemble: false, gcn: true },
        ann_cfg: fso::models::TrainConfig { max_epochs: 30, early_stop: 10, ..Default::default() },
        gcn_cfg: fso::models::TrainConfig {
            max_epochs: 10,
            early_stop: 5,
            lr0: 8e-3,
            ..Default::default()
        },
        ..Default::default()
    };
    let report = trainer
        .run(&g.dataset, &g.backend_split, Metric::Performance, &opts)
        .expect("train");
    let ann = &report.models["ANN"];
    let gcn = &report.models["GCN"];
    // both must clearly beat a 100%-off baseline; ANN should be decent
    assert!(ann.mu_ape < 30.0, "ANN muAPE {:.1}%", ann.mu_ape);
    assert!(gcn.mu_ape < 60.0, "GCN muAPE {:.1}%", gcn.mu_ape);
}
