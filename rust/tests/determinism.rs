//! Determinism regression tests (ISSUE 1): at a fixed seed the whole
//! stack must be bit-reproducible, and the `EvalService`'s parallelism
//! must never change results — serial and parallel runs of datagen and
//! DSE produce byte-identical rows / Pareto fronts.

use fso::backend::{BackendConfig, Enablement, SpnrFlow};
use fso::coordinator::dse_driver::{axiline_svm_problem, DseDriver, DseOutcome, SurrogateBundle};
use fso::coordinator::{datagen, DatagenConfig, EvalService, GeneratedData};
use fso::dse::MotpeConfig;
use fso::generators::{ArchConfig, Platform};

fn mid_arch(p: Platform) -> ArchConfig {
    ArchConfig::new(
        p,
        p.param_space().iter().map(|s| s.kind.from_unit(0.5)).collect(),
    )
}

#[test]
fn spnr_flow_ppa_identical_across_instances() {
    for p in Platform::ALL {
        let arch = mid_arch(p);
        for cfg in [BackendConfig::new(0.6, 0.35), BackendConfig::new(1.1, 0.5)] {
            let a = SpnrFlow::new(Enablement::Gf12, 42).run(&arch, cfg).unwrap();
            let b = SpnrFlow::new(Enablement::Gf12, 42).run(&arch, cfg).unwrap();
            assert_eq!(a.backend, b.backend, "{p}: P&R PPA must be seed-determined");
            assert_eq!(a.synth, b.synth, "{p}: synthesis must be seed-determined");
        }
    }
}

#[test]
fn eval_service_matches_bare_flow_and_is_worker_invariant() {
    let arch = mid_arch(Platform::Vta);
    let cfg = BackendConfig::new(0.9, 0.45);
    let bare = SpnrFlow::new(Enablement::Gf12, 5).run(&arch, cfg).unwrap();
    for workers in [1, 4] {
        let svc = EvalService::new(Enablement::Gf12, 5).with_workers(workers);
        let ev = svc.evaluate(&arch, cfg, None).unwrap();
        assert_eq!(ev.flow.backend, bare.backend);
    }
}

fn small_cfg(workers: usize) -> DatagenConfig {
    DatagenConfig {
        n_arch: 4,
        n_backend_train: 6,
        n_backend_test: 2,
        workers,
        ..DatagenConfig::small(Platform::Axiline, Enablement::Gf12)
    }
}

#[test]
fn datagen_rows_identical_serial_vs_parallel() {
    let serial = datagen::generate(&small_cfg(1)).unwrap();
    let parallel = datagen::generate(&small_cfg(4)).unwrap();
    assert_eq!(serial.dataset.rows, parallel.dataset.rows);
    assert_eq!(serial.backend_split.train, parallel.backend_split.train);
    assert_eq!(serial.backend_split.test, parallel.backend_split.test);
    // and repeat runs at the same seed reproduce exactly
    let again = datagen::generate(&small_cfg(4)).unwrap();
    assert_eq!(parallel.dataset.rows, again.dataset.rows);
}

fn run_dse(g: &GeneratedData, workers: usize, batch: usize) -> DseOutcome {
    let surrogate = SurrogateBundle::fit(&g.dataset, &g.backend_split, 1).unwrap();
    let driver = DseDriver::new(Enablement::Gf12, surrogate, 2023).with_workers(workers);
    let mut runtimes: Vec<f64> = g.dataset.rows.iter().map(|r| r.runtime_s).collect();
    runtimes.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let problem = axiline_svm_problem(
        g.dataset.rows.iter().map(|r| r.power_w).fold(0.0, f64::max) * 2.0,
        runtimes[runtimes.len() * 3 / 4],
    );
    driver
        .run_batched(
            &problem,
            48,
            2,
            MotpeConfig { n_startup: 16, seed: 3, ..Default::default() },
            batch,
        )
        .unwrap()
}

#[test]
fn dse_pareto_front_identical_serial_vs_parallel() {
    let mut cfg = DatagenConfig::small(Platform::Axiline, Enablement::Gf12);
    cfg.n_arch = 8;
    cfg.n_backend_train = 12;
    cfg.n_backend_test = 4;
    let g = datagen::generate(&cfg).unwrap();

    let serial = run_dse(&g, 1, 8);
    let parallel = run_dse(&g, 4, 8);

    // byte-identical trajectory, winners, ground truth, and front
    assert_eq!(serial.points, parallel.points);
    assert_eq!(serial.best, parallel.best);
    assert_eq!(serial.ground_truth_errors, parallel.ground_truth_errors);
    assert_eq!(serial.pareto_front(), parallel.pareto_front());
    // the front is exactly reproducible across repeat runs too
    let again = run_dse(&g, 4, 8);
    assert_eq!(parallel.pareto_front(), again.pareto_front());
}

#[test]
fn surrogate_fit_is_deterministic() {
    let cfg = DatagenConfig {
        n_arch: 8,
        n_backend_train: 12,
        n_backend_test: 4,
        ..DatagenConfig::small(Platform::Vta, Enablement::Gf12)
    };
    let g = datagen::generate(&cfg).unwrap();
    let a = SurrogateBundle::fit(&g.dataset, &g.backend_split, 7).unwrap();
    let b = SurrogateBundle::fit(&g.dataset, &g.backend_split, 7).unwrap();
    for row in &g.dataset.rows {
        let (ra, pa) = a.predict(&row.features_vec());
        let (rb, pb) = b.predict(&row.features_vec());
        assert_eq!(ra, rb);
        assert_eq!(pa, pb);
    }
}

#[test]
fn trial_streams_reproducible_and_independent() {
    let arch = mid_arch(Platform::GeneSys);
    let cfg = BackendConfig::new(0.8, 0.4);
    let s1 = EvalService::new(Enablement::Gf12, 99);
    let s2 = EvalService::new(Enablement::Gf12, 99);
    for trial in 0..3u64 {
        let a = s1.evaluate_trial(&arch, cfg, None, trial).unwrap();
        let b = s2.evaluate_trial(&arch, cfg, None, trial).unwrap();
        assert_eq!(a.flow.backend, b.flow.backend, "trial {trial} must replay");
    }
    let t0 = s1.evaluate_trial(&arch, cfg, None, 0).unwrap();
    let t1 = s1.evaluate_trial(&arch, cfg, None, 1).unwrap();
    assert_ne!(
        t0.flow.backend.f_effective_ghz, t1.flow.backend.f_effective_ghz,
        "distinct trials must draw independent tool noise"
    );
}
