//! Flat SoA forest inference (the PR-6 tentpole): differential
//! bit-identity between the flattened batch walkers and the recursive
//! reference walkers — NaN/±Inf/-0.0 features included — across every
//! tree family, through disk round-trips and model-store warm starts,
//! plus the call-count regression test pinning that every batch caller
//! stays batched (no per-row fallback anywhere on the surrogate path).

use std::path::PathBuf;

use fso::backend::Enablement;
use fso::coordinator::dse_driver::SurrogateBundle;
use fso::coordinator::{datagen, DatagenConfig, EvalService, ModelStore};
use fso::data::Metric;
use fso::generators::Platform;
use fso::models::{
    tune_gbdt, tune_rf, Gbdt, GbdtClassifier, GbdtParams, RandomForest, RfParams,
    RoiClassifier, SearchBudget, TunedGbdt, TunedRf,
};
use fso::util::json::Json;
use fso::util::prop::check;
use fso::util::rng::Rng;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("fso-flat-tree-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Serialize -> print -> parse -> deserialize: the exact disk path.
fn disk_roundtrip(j: Json) -> Json {
    Json::parse(&j.to_string()).expect("serialized model must re-parse")
}

/// One of the IEEE special values the split comparison must route
/// identically in both walkers (`x <= thr` is false for NaN; ±Inf and
/// -0.0 compare by the usual total order of `<=`).
fn special(rng: &mut Rng) -> f64 {
    match rng.below(4) {
        0 => f64::NAN,
        1 => f64::INFINITY,
        2 => f64::NEG_INFINITY,
        _ => -0.0,
    }
}

/// A query matrix over `d` features where roughly `p_special` of the
/// cells are NaN/±Inf/-0.0 and the rest are uniform.
fn query_matrix(rng: &mut Rng, rows: usize, d: usize, p_special: f64) -> Vec<Vec<f64>> {
    (0..rows)
        .map(|_| {
            (0..d)
                .map(|_| {
                    if rng.bool(p_special) {
                        special(rng)
                    } else {
                        rng.f64() * 4.0 - 2.0
                    }
                })
                .collect()
        })
        .collect()
}

fn assert_bits_eq(flat: &[f64], reference: &[f64], what: &str) {
    assert_eq!(flat.len(), reference.len(), "{what}: length mismatch");
    for (i, (a, b)) in flat.iter().zip(reference).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{what}: row {i} diverged (flat {a:?} vs reference {b:?})"
        );
    }
}

/// Satellite 1: arbitrary fitted forests x arbitrary query matrices
/// (special values injected into training *and* queries) — the flat
/// batch path reproduces the recursive per-row walkers bit-for-bit at
/// every worker count.
#[test]
fn prop_flat_batch_matches_recursive_walkers_bitwise() {
    check(10, 0xF1A7, |rng| {
        let n = 40 + rng.below(40);
        let d = 2 + rng.below(5);
        // training data: mostly finite, a few NaN cells (the tree
        // builder tolerates them; ±Inf-adjacent midpoints are rejected
        // as thresholds at fit time, so fits stay valid)
        let x: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                (0..d)
                    .map(|_| if rng.bool(0.02) { f64::NAN } else { rng.f64() * 3.0 })
                    .collect()
            })
            .collect();
        let y: Vec<f64> = x
            .iter()
            .map(|v| {
                let v0 = if v[0].is_nan() { 0.0 } else { v[0] };
                2.0 * v0 - v[1 % d].abs().min(5.0) + 0.1 * rng.normal()
            })
            .collect();
        let labels: Vec<bool> = y.iter().map(|&v| v > 1.0).collect();

        let params = GbdtParams { n_estimators: 12, max_depth: 3, ..Default::default() };
        let gbdt = Gbdt::fit(&x, &y, params, rng.next_u64());
        let cls = GbdtClassifier::fit(&x, &labels, params, rng.next_u64());
        let rf = RandomForest::fit(
            &x,
            &y,
            RfParams { n_estimators: 10, max_depth: 6, ..Default::default() },
            rng.next_u64(),
        );

        let queries = query_matrix(rng, 10 + rng.below(300), d, 0.2);
        let g_ref: Vec<f64> = queries.iter().map(|q| gbdt.predict_one(q)).collect();
        let c_ref: Vec<f64> = queries.iter().map(|q| cls.prob_one(q)).collect();
        let r_ref: Vec<f64> = queries.iter().map(|q| rf.predict_one(q)).collect();
        for workers in [1usize, 3, 8] {
            assert_bits_eq(
                &gbdt.predict_with(&queries, workers),
                &g_ref,
                &format!("gbdt w={workers}"),
            );
            assert_bits_eq(
                &cls.probs_with(&queries, workers),
                &c_ref,
                &format!("classifier w={workers}"),
            );
            assert_bits_eq(
                &rf.predict_with(&queries, workers),
                &r_ref,
                &format!("rf w={workers}"),
            );
        }
    });
}

/// Satellite 2 (first half): every serializable tree family's disk
/// round-trip re-flattens on load, and the deserialized model's *batch*
/// predictions match the original model's *recursive* reference — so
/// flattening composes with persistence without touching a bit, even
/// on special-value queries.
#[test]
fn persisted_families_reflatten_with_bit_exact_batch_predictions() {
    let mut rng = Rng::new(41);
    let x: Vec<Vec<f64>> =
        (0..160).map(|_| (0..6).map(|_| rng.f64()).collect()).collect();
    let y: Vec<f64> =
        x.iter().map(|v| 4.0 * v[0] * v[1] + v[2] - 2.0 * v[3] + 0.1 * v[4]).collect();
    let labels: Vec<bool> = y.iter().map(|&v| v > 1.5).collect();
    let (x_val, y_val) = {
        let xv: Vec<Vec<f64>> =
            (0..50).map(|_| (0..6).map(|_| rng.f64()).collect()).collect();
        let yv: Vec<f64> = xv
            .iter()
            .map(|v| 4.0 * v[0] * v[1] + v[2] - 2.0 * v[3] + 0.1 * v[4])
            .collect();
        (xv, yv)
    };
    // hold-out queries include NaN/±Inf/-0.0 cells
    let hold = query_matrix(&mut rng, 80, 6, 0.15);

    let params = GbdtParams { n_estimators: 40, ..Default::default() };
    let gbdt = Gbdt::fit(&x, &y, params, 5);
    let gbdt2 = Gbdt::from_json(&disk_roundtrip(gbdt.to_json())).expect("gbdt");
    let reference: Vec<f64> = hold.iter().map(|q| gbdt.predict_one(q)).collect();
    assert_bits_eq(&gbdt2.predict(&hold), &reference, "gbdt roundtrip");

    let cls = GbdtClassifier::fit(&x, &labels, params, 5);
    let cls2 = GbdtClassifier::from_json(&disk_roundtrip(cls.to_json())).expect("cls");
    let reference: Vec<f64> = hold.iter().map(|q| cls.prob_one(q)).collect();
    assert_bits_eq(&cls2.probs(&hold), &reference, "classifier roundtrip");

    let rf = RandomForest::fit(&x, &y, RfParams { n_estimators: 30, ..Default::default() }, 5);
    let rf2 = RandomForest::from_json(&disk_roundtrip(rf.to_json())).expect("rf");
    let reference: Vec<f64> = hold.iter().map(|q| rf.predict_one(q)).collect();
    assert_bits_eq(&rf2.predict(&hold), &reference, "rf roundtrip");

    let roi = RoiClassifier::fit(&x, &labels, 5);
    let roi2 = RoiClassifier::from_json(&disk_roundtrip(roi.to_json())).expect("roi");
    let reference: Vec<f64> = hold.iter().map(|q| roi.prob(q)).collect();
    assert_bits_eq(&roi2.probs(&hold), &reference, "roi roundtrip");

    // tuned families persist (params, model) — the reloaded model's
    // batch path must match the original's recursive walk too
    let budget = SearchBudget { stage1: 3, stage2: 2, seed: 1 };
    let tg = tune_gbdt(&x, &y, &x_val, &y_val, budget);
    let tg2 = TunedGbdt::from_json(&disk_roundtrip(tg.to_json())).expect("tuned gbdt");
    let reference: Vec<f64> = hold.iter().map(|q| tg.model.predict_one(q)).collect();
    assert_bits_eq(&tg2.model.predict(&hold), &reference, "tuned gbdt roundtrip");
    let tr = tune_rf(&x, &y, &x_val, &y_val, budget);
    let tr2 = TunedRf::from_json(&disk_roundtrip(tr.to_json())).expect("tuned rf");
    let reference: Vec<f64> = hold.iter().map(|q| tr.model.predict_one(q)).collect();
    assert_bits_eq(&tr2.model.predict(&hold), &reference, "tuned rf roundtrip");
}

fn small_cfg() -> DatagenConfig {
    DatagenConfig {
        n_arch: 6,
        n_backend_train: 10,
        n_backend_test: 4,
        ..DatagenConfig::small(Platform::Axiline, Enablement::Gf12)
    }
}

/// Per-row recursive reference for the full two-stage bundle: the ROI
/// gate from the classifier's recursive probability, each metric from
/// the regressor's recursive walk + the log-space inverse.
fn bundle_reference(
    bundle: &SurrogateBundle,
    feats: &[Vec<f64>],
) -> Vec<(bool, Vec<(Metric, f64)>)> {
    feats
        .iter()
        .map(|x| {
            let gate = bundle.classifier.prob(x) >= 0.5;
            let preds = Metric::ALL
                .into_iter()
                .map(|m| (m, bundle.regressors[&m].predict_one(x).exp()))
                .collect();
            (gate, preds)
        })
        .collect()
}

fn assert_bundle_matches(
    got: &[(bool, std::collections::BTreeMap<Metric, f64>)],
    want: &[(bool, Vec<(Metric, f64)>)],
    what: &str,
) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, ((g_roi, g_pred), (w_roi, w_pred))) in got.iter().zip(want).enumerate() {
        assert_eq!(g_roi, w_roi, "{what}: row {i} ROI gate diverged");
        for (m, w) in w_pred {
            assert_eq!(
                g_pred[m].to_bits(),
                w.to_bits(),
                "{what}: row {i} metric {m} not bit-identical"
            );
        }
    }
}

/// Satellite 2 (second half): a model-store warm start hands back a
/// bundle whose *flat batch* predictions are bit-identical to the cold
/// fit's *recursive* reference, at any worker count.
#[test]
fn warm_started_bundle_predicts_bit_identically_through_flat_batches() {
    let dir = tmp_dir("warm");
    let g = datagen::generate(&small_cfg()).unwrap();
    let feats: Vec<Vec<f64>> =
        g.dataset.rows.iter().map(|r| r.features_vec()).collect();

    let reference = {
        let store = ModelStore::open(&dir).unwrap();
        let (bundle, replayed) =
            SurrogateBundle::fit_cached(&g.dataset, &g.backend_split, 7, Some(&store))
                .unwrap();
        assert!(!replayed, "empty store cannot replay");
        store.flush().unwrap();
        bundle_reference(&bundle, &feats)
    };

    let store = ModelStore::open(&dir).unwrap();
    let (bundle, replayed) =
        SurrogateBundle::fit_cached(&g.dataset, &g.backend_split, 7, Some(&store)).unwrap();
    assert!(replayed, "reopened store must serve the artifact");
    for workers in [1usize, 5] {
        let warm = bundle.predict_batch(&feats, workers);
        assert_bundle_matches(&warm, &reference, &format!("warm flat batch w={workers}"));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite 3: the call-count regression test. A `predict_batch` of
/// `n` rows is exactly `1 + Metric::ALL.len()` flat batch entries and
/// `(1 + Metric::ALL.len()) * n` flat rows — through the bundle, the
/// single-row wrapper, and the `EvalService` — so no caller can
/// silently degrade to per-row scoring (the pre-flat hot spot) without
/// failing here.
#[test]
fn surrogate_batch_callers_stay_batched() {
    let passes = 1 + Metric::ALL.len(); // classifier + 5 metric regressors
    let g = datagen::generate(&small_cfg()).unwrap();
    let feats: Vec<Vec<f64>> =
        g.dataset.rows.iter().map(|r| r.features_vec()).collect();
    let n = feats.len();
    assert!(n > 0);

    let bundle = SurrogateBundle::fit(&g.dataset, &g.backend_split, 7).unwrap();
    assert_eq!(bundle.flat_stats(), (0, 0), "fitting never scores through flat");

    // one mega-batch: one flat entry per forest, n rows each
    bundle.predict_batch(&feats, 3);
    assert_eq!(bundle.flat_stats(), (passes, passes * n));
    // the classifier specifically used to be the per-row fallback
    // (one `prob` per row); now it is exactly one batch of n rows
    assert_eq!(bundle.classifier.flat_stats(), (1, n));

    // the single-row wrapper is a batch of one, not a different path
    bundle.predict(&feats[0]);
    assert_eq!(bundle.flat_stats(), (2 * passes, passes * n + passes));

    // through the service (what the DSE driver and router call): same
    // counts, shifted by what the bundle has already scored
    let svc = EvalService::new(Enablement::Gf12, 2023)
        .with_surrogate(bundle)
        .with_workers(4);
    svc.predict_batch(&feats).unwrap();
    let (batches, rows) = svc.surrogate().unwrap().flat_stats();
    assert_eq!((batches, rows), (3 * passes, 2 * passes * n + passes));
    // empty batches short-circuit before any counter
    svc.predict_batch(&[]).unwrap();
    assert_eq!(svc.surrogate().unwrap().flat_stats(), (3 * passes, 2 * passes * n + passes));

    let s = svc.stats();
    assert_eq!(s.surrogate_batches, 1);
    assert_eq!(s.surrogate_rows, n);
}
