//! Coalescing stress tests (ISSUE 5 satellite): a duplicate-heavy key
//! mix hammered by threads in-process, plus a spawned `fso datagen
//! --coalesce` process pair sharing one `--cache-dir` — asserting the
//! schedule-independent counter invariants (`oracle_runs == unique
//! keys`, hits + misses == total calls) and byte-identical outputs
//! vs. serial reference runs. No hooks here: these runs take whatever
//! interleavings the scheduler produces, and the invariants must hold
//! on all of them.

use std::fs;
use std::path::PathBuf;
use std::process::Command;

use fso::backend::{BackendConfig, Enablement};
use fso::coordinator::{datagen, CacheStore, EvalService};
use fso::generators::{ArchConfig, Platform};
use fso::sampling::SamplerKind;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("fso-coalesce-stress-{}-{tag}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    d
}

#[test]
fn thread_hammer_on_duplicate_heavy_keys_holds_counter_invariants() {
    // 6 unique (arch, backend) points, hammered by 8 threads x 30
    // calls in round-robin (every thread touches every key, so the
    // duplicate pressure is maximal and coverage is deterministic)
    let archs = datagen::sample_archs(Platform::Axiline, 3, SamplerKind::Lhs, 11);
    let uniques: Vec<(ArchConfig, BackendConfig)> = archs
        .iter()
        .flat_map(|a| {
            [BackendConfig::new(0.7, 0.5), BackendConfig::new(1.1, 0.45)]
                .into_iter()
                .map(move |b| (a.clone(), b))
        })
        .collect();
    assert!(uniques.len() >= 4, "need a duplicate-heavy mix, got {}", uniques.len());

    let dir = tmp_dir("hammer");
    let store = std::sync::Arc::new(CacheStore::open(&dir).unwrap());
    let svc = EvalService::new(Enablement::Gf12, 7)
        .with_coalescing(true)
        .with_cache_store(std::sync::Arc::clone(&store));
    const THREADS: usize = 8;
    const CALLS: usize = 30;
    std::thread::scope(|scope| {
        for t in 0..THREADS {
            let svc = &svc;
            let uniques = &uniques;
            scope.spawn(move || {
                for k in 0..CALLS {
                    let (arch, bcfg) = &uniques[(t + k) % uniques.len()];
                    svc.evaluate(arch, *bcfg, None).unwrap();
                }
            });
        }
    });

    let s = svc.stats();
    let total = THREADS * CALLS;
    assert_eq!(
        s.oracle_runs,
        uniques.len(),
        "single-flight must run the oracle exactly once per unique key: {s}"
    );
    assert_eq!(s.flow_runs, uniques.len(), "{s}");
    assert_eq!(s.oracle_misses, uniques.len(), "{s}");
    assert_eq!(s.oracle_hits, total - uniques.len(), "{s}");
    assert_eq!(s.oracle_hits + s.oracle_misses, total, "{s}");
    assert!(s.coalesced_hits <= s.oracle_hits, "{s}");
    assert!(s.inflight_peak >= 1 && s.inflight_peak <= uniques.len(), "{s}");

    // the store saw exactly one flow + one eval record per unique key
    assert_eq!(store.stats().pending, 2 * uniques.len(), "store written once per key");
    store.flush().unwrap();

    // byte-identical to a serial, uncoalesced reference
    let reference = EvalService::new(Enablement::Gf12, 7);
    for (arch, bcfg) in &uniques {
        let want = reference.evaluate(arch, *bcfg, None).unwrap();
        let got = svc.evaluate(arch, *bcfg, None).unwrap(); // memo replay
        assert_eq!(got.flow.backend, want.flow.backend);
        assert_eq!(got.flow.synth, want.flow.synth);
        assert_eq!(got.system, want.system);
    }
    let _ = fs::remove_dir_all(&dir);
}

fn datagen_cmd(
    enablement: &str,
    cache_dir: Option<&PathBuf>,
    coalesce: bool,
    out: Option<&PathBuf>,
) -> Command {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_fso"));
    cmd.args([
        "datagen",
        "--platform",
        "axiline",
        "--archs",
        "2",
        "--seed",
        "7",
        "--enablement",
        enablement,
    ]);
    if coalesce {
        cmd.arg("--coalesce");
    }
    if let Some(dir) = cache_dir {
        cmd.arg("--cache-dir").arg(dir);
    }
    if let Some(path) = out {
        cmd.arg("--out").arg(path);
    }
    cmd
}

fn live_entries(dir: &PathBuf) -> usize {
    let store = CacheStore::open(dir).unwrap();
    store.load_all();
    store.stats().entries
}

#[test]
fn spawned_coalesced_datagen_pair_merges_and_matches_serial_csv() {
    // serial reference: no cache, no coalescing
    let serial_csv = tmp_dir("serial-csv").with_extension("csv");
    let out = datagen_cmd("gf12", None, false, Some(&serial_csv))
        .output()
        .expect("spawn serial fso datagen");
    assert!(out.status.success(), "serial datagen failed: {out:?}");

    // the race: two coalesced processes, one cache dir
    let shared = tmp_dir("shared");
    let coal_csv = tmp_dir("coal-csv").with_extension("csv");
    let mut a = datagen_cmd("gf12", Some(&shared), true, Some(&coal_csv))
        .spawn()
        .expect("spawn coalesced gf12");
    let mut b = datagen_cmd("ng45", Some(&shared), true, None)
        .spawn()
        .expect("spawn coalesced ng45");
    let sa = a.wait().expect("wait gf12");
    let sb = b.wait().expect("wait ng45");
    assert!(sa.success() && sb.success(), "coalesced datagen pair failed");

    // byte-identical CSV vs. the serial reference run
    assert_eq!(
        fs::read(&serial_csv).unwrap(),
        fs::read(&coal_csv).unwrap(),
        "coalescing changed the generated rows"
    );

    // union survived the concurrent flushes: both enablements' records
    // live (their key sets are disjoint) and the lock was released
    let solo = tmp_dir("solo");
    let out = datagen_cmd("gf12", Some(&solo), true, None)
        .output()
        .expect("spawn solo gf12");
    assert!(out.status.success(), "solo gf12 failed: {out:?}");
    let solo_gf = live_entries(&solo);
    assert!(solo_gf > 0);
    assert!(
        live_entries(&shared) > solo_gf,
        "shared store must hold both enablements' records"
    );
    assert!(
        !shared.join(".store.lock").exists(),
        "both processes must release the directory lock"
    );

    // a coalesced warm rerun replays entirely from disk
    let out = datagen_cmd("gf12", Some(&shared), true, None)
        .output()
        .expect("spawn warm coalesced datagen");
    assert!(out.status.success(), "warm coalesced datagen failed: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("100.0% cached"),
        "warm coalesced rerun must be fully cached:\n{stdout}"
    );
    assert!(
        !stdout.contains("persistent 0 disk hits"),
        "warm coalesced rerun must hit the persistent store:\n{stdout}"
    );

    let _ = fs::remove_file(&serial_csv);
    let _ = fs::remove_file(&coal_csv);
    let _ = fs::remove_dir_all(&shared);
    let _ = fs::remove_dir_all(&solo);
}
