//! Differential property tests for the streaming JSON tokenizer
//! (ISSUE 7 satellite a): `util::json::JsonTokenizer` and `lazy_get`
//! must accept and reject *exactly* the documents the tree parser
//! does, and every f64 that flows through them must come out
//! bit-identical — the storage engine's lazy shard loads stand on that
//! equivalence.

use std::collections::BTreeMap;

use fso::util::json::{lazy_get, Json, JsonToken, JsonTokenizer};
use fso::util::prop::check;
use fso::util::rng::Rng;

/// Random JSON value with bounded depth; finite numbers only (the
/// writer side never emits non-finite values — they render as null).
fn random_json(rng: &mut Rng, depth: usize) -> Json {
    let pick = if depth == 0 { rng.below(5) } else { rng.below(7) };
    match pick {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => Json::Num(random_f64(rng)),
        3 => Json::Str(random_string(rng)),
        4 => Json::Num(rng.below(1_000_000) as f64),
        5 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => {
            let mut m = BTreeMap::new();
            for _ in 0..rng.below(4) {
                m.insert(random_string(rng), random_json(rng, depth - 1));
            }
            Json::Obj(m)
        }
    }
}

/// Finite f64 across many orders of magnitude, occasionally adversarial.
fn random_f64(rng: &mut Rng) -> f64 {
    match rng.below(6) {
        0 => 0.0,
        1 => -0.0,
        2 => (rng.next_u64() as i64) as f64,
        3 => rng.f64(),
        _ => {
            let v = (rng.f64() - 0.5) * 10f64.powi(rng.int_range(-250, 250) as i32);
            if v.is_finite() {
                v
            } else {
                rng.f64()
            }
        }
    }
}

/// Strings mixing plain ASCII, escapes, and multi-byte UTF-8.
fn random_string(rng: &mut Rng) -> String {
    const POOL: &[&str] =
        &["a", "key", "\"", "\\", "\n", "\t", "\u{1F600}", "é", "x y", "0", "\u{0}"];
    (0..rng.below(5)).map(|_| POOL[rng.below(POOL.len())]).collect()
}

/// Rebuild a full value tree by walking the token stream — the
/// reference decode the streaming store paths must be equivalent to.
fn rebuild(t: &mut JsonTokenizer<'_>) -> Json {
    let tok = t.next().expect("tokenizer accepts what the tree parser accepted");
    rebuild_from(tok.expect("value expected"), t)
}

fn rebuild_from(tok: JsonToken<'_>, t: &mut JsonTokenizer<'_>) -> Json {
    match tok {
        JsonToken::Null => Json::Null,
        JsonToken::Bool(b) => Json::Bool(b),
        JsonToken::Num(n) => Json::Num(n),
        JsonToken::Str(s) => Json::Str(s.into_owned()),
        JsonToken::ArrBegin => {
            let mut items = Vec::new();
            loop {
                match t.next().unwrap().expect("array items or close") {
                    JsonToken::ArrEnd => return Json::Arr(items),
                    tok => items.push(rebuild_from(tok, t)),
                }
            }
        }
        JsonToken::ObjBegin => {
            let mut m = BTreeMap::new();
            loop {
                match t.next().unwrap().expect("object keys or close") {
                    JsonToken::ObjEnd => return Json::Obj(m),
                    JsonToken::Key(k) => {
                        let v = rebuild(t);
                        m.insert(k.into_owned(), v);
                    }
                    other => panic!("unexpected token in object: {other:?}"),
                }
            }
        }
        other => panic!("unexpected value token: {other:?}"),
    }
}

/// Drive the tokenizer over a document to completion (or first error).
fn tokenize_all(bytes: &[u8]) -> Result<Vec<String>, String> {
    let mut t = JsonTokenizer::new(bytes);
    let mut toks = Vec::new();
    loop {
        match t.next() {
            Ok(Some(tok)) => toks.push(format!("{tok:?}")),
            Ok(None) => return Ok(toks),
            Err(e) => return Err(format!("{e:?}")),
        }
    }
}

fn bits(j: &Json) -> Vec<u64> {
    match j {
        Json::Num(n) => vec![n.to_bits()],
        Json::Arr(xs) => xs.iter().flat_map(bits).collect(),
        Json::Obj(m) => m.values().flat_map(bits).collect(),
        _ => Vec::new(),
    }
}

#[test]
fn prop_token_walk_rebuilds_the_tree_parse_bit_exactly() {
    check(400, 0x70CE1, |rng| {
        let value = random_json(rng, 3);
        let text = value.to_string();
        let parsed = Json::parse(&text).expect("rendered JSON re-parses");
        let rebuilt = rebuild(&mut JsonTokenizer::new(text.as_bytes()));
        assert_eq!(rebuilt, parsed, "token walk diverged on {text}");
        assert_eq!(
            bits(&rebuilt),
            bits(&parsed),
            "f64 bit patterns diverged on {text}"
        );
        assert_eq!(rebuilt.to_string(), text, "round-trip render changed {text}");
    });
}

#[test]
fn prop_tokenizer_accepts_exactly_what_the_tree_parser_accepts() {
    check(400, 0xACCE97, |rng| {
        let value = random_json(rng, 2);
        let mut text = value.to_string();
        // random mutation: truncate, splice a byte, or append garbage
        match rng.below(4) {
            0 => {
                let mut cut = rng.below(text.len() + 1);
                while !text.is_char_boundary(cut) {
                    cut -= 1;
                }
                text.truncate(cut);
            }
            1 => {
                let junk = ["}", "]", ",", ":", "x", "1", "\"", " "][rng.below(8)];
                let at = rng.below(text.len() + 1);
                if text.is_char_boundary(at) {
                    text.insert_str(at, junk);
                }
            }
            2 => text.push_str(["tail", "{}", "  ", "null"][rng.below(4)]),
            _ => {} // unmodified: both must accept
        }
        let tree = Json::parse(&text);
        let stream = tokenize_all(text.as_bytes());
        assert_eq!(
            tree.is_ok(),
            stream.is_ok(),
            "acceptance diverged on {text:?}: tree={tree:?} stream={stream:?}"
        );
    });
}

#[test]
fn prop_lazy_get_matches_tree_lookup_and_rejects_torn_docs() {
    check(300, 0x1A27, |rng| {
        let mut m = BTreeMap::new();
        for _ in 0..1 + rng.below(5) {
            m.insert(random_string(rng), random_json(rng, 2));
        }
        let doc = Json::Obj(m.clone());
        let text = doc.to_string();
        for key in m.keys() {
            let span = lazy_get(text.as_bytes(), key)
                .expect("valid doc scans")
                .expect("present key found");
            let body = Json::parse(std::str::from_utf8(span).unwrap()).unwrap();
            assert_eq!(&body, doc.get(key), "lazy span diverged for key {key:?}");
        }
        assert_eq!(lazy_get(text.as_bytes(), "\u{1}no-such-key").unwrap(), None);
        // a torn tail must error, never half-succeed with a found span
        let cut = rng.below(text.len());
        if cut > 0 && text.is_char_boundary(cut) {
            assert!(
                lazy_get(&text.as_bytes()[..cut], m.keys().next().unwrap()).is_err(),
                "torn doc (cut at {cut}) must not scan cleanly: {text}"
            );
        }
    });
}
