//! End-to-end smoke (ISSUE 1): tiny datagen -> `SurrogateBundle::fit`
//! -> short batched DSE run, all through one shared `EvalService`, then
//! assert a non-empty feasible Pareto front and a nonzero cache hit
//! rate in the service stats.

use fso::backend::Enablement;
use fso::coordinator::dse_driver::{axiline_svm_problem, DseDriver, SurrogateBundle};
use fso::coordinator::{datagen, DatagenConfig, EvalService};
use fso::dse::MotpeConfig;
use fso::generators::Platform;

#[test]
fn datagen_fit_dse_through_one_service() {
    // one service shared by datagen and DSE: caches carry across phases
    let mut cfg = DatagenConfig::small(Platform::Axiline, Enablement::Gf12);
    cfg.n_arch = 6;
    cfg.n_backend_train = 10;
    cfg.n_backend_test = 4;
    let service = EvalService::new(cfg.enablement, cfg.seed).with_workers(2);
    let g = datagen::generate_with(&service, &cfg).expect("datagen");
    assert_eq!(g.dataset.len(), 6 * 14);

    let surrogate = SurrogateBundle::fit(&g.dataset, &g.backend_split, 1).expect("fit");
    let driver = DseDriver {
        service: service.with_surrogate(surrogate),
    };

    let mut runtimes: Vec<f64> = g.dataset.rows.iter().map(|r| r.runtime_s).collect();
    runtimes.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let problem = axiline_svm_problem(
        g.dataset.rows.iter().map(|r| r.power_w).fold(0.0, f64::max) * 2.0,
        runtimes[runtimes.len() * 3 / 4],
    );
    let outcome = driver
        .run_batched(
            &problem,
            60,
            2,
            MotpeConfig { n_startup: 16, seed: 5, ..Default::default() },
            12,
        )
        .expect("dse");

    assert_eq!(outcome.points.len(), 60);
    let front = outcome.pareto_front();
    assert!(!front.is_empty(), "no feasible Pareto front found");
    for &i in &front {
        assert!(outcome.points[i].feasible, "front member {i} infeasible");
    }
    assert!(!outcome.best.is_empty(), "Eq. 3 selected no winners");
    for errs in &outcome.ground_truth_errors {
        for (_, e) in errs {
            assert!(e.is_finite());
        }
    }

    let stats = driver.stats();
    // datagen ran the full cartesian sweep through the service: every
    // arch's aggregates were looked up once per backend point, so the
    // cache hit rate is strictly positive; the surrogate path must have
    // batched the DSE traffic rather than predicting row-by-row
    assert!(stats.cache_hit_rate() > 0.0, "cache hit rate was 0: {stats}");
    assert!(stats.agg_hits > 0, "aggregate cache never hit: {stats}");
    assert!(stats.oracle_misses > 0, "oracle never ran: {stats}");
    assert!(stats.surrogate_rows >= 60, "DSE rows not scored via service: {stats}");
    assert!(
        stats.mean_batch_occupancy() > 1.0,
        "surrogate traffic was not batched: {stats}"
    );
}
