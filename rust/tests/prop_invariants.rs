//! Property-based invariants over the coordinator substrates (DESIGN.md
//! §7), via the in-repo mini property harness (proptest is unavailable
//! offline). Each property runs across many seeded random cases and
//! reports a replayable (seed, fork) pair on failure.

use fso::backend::{BackendConfig, Enablement, SpnrFlow};
use fso::data::dataset::Dataset;
use fso::dse::{dominates, ParetoFront};
use fso::generators::{ArchConfig, Lhg, Platform};
use fso::runtime::Batcher;
use fso::sampling::{Sampler, SamplerKind};
use fso::util::prop::check;
use fso::util::rng::Rng;

fn random_platform(rng: &mut Rng) -> Platform {
    Platform::ALL[rng.below(4)]
}

fn random_arch(rng: &mut Rng, p: Platform) -> ArchConfig {
    let vals = p
        .param_space()
        .iter()
        .map(|s| s.kind.from_unit(rng.f64()))
        .collect();
    ArchConfig::new(p, vals)
}

#[test]
fn prop_batcher_covers_every_request_exactly_once_in_order() {
    check(200, 0xBA7C, |rng| {
        let b = Batcher::new(1 + rng.below(64));
        let n = rng.below(500);
        let plans = b.plan(n);
        let mut seen = Vec::new();
        for p in &plans {
            assert!(p.rows.len() <= p.batch_size);
            seen.extend_from_slice(&p.rows);
        }
        assert_eq!(seen, (0..n).collect::<Vec<_>>());
        // only the final plan may be partial
        for p in plans.iter().rev().skip(1) {
            assert_eq!(p.padding(), 0);
        }
    });
}

#[test]
fn prop_lhg_is_always_a_tree_within_budget() {
    check(120, 0x16C, |rng| {
        let p = random_platform(rng);
        let arch = random_arch(rng, p);
        let tree = p.generate(&arch).unwrap();
        let lhg = Lhg::from_tree(&tree);
        lhg.validate().unwrap();
        assert!(lhg.len() <= fso::generators::lhg::MAX_NODES);
        let (_, adj, mask) = lhg.to_gcn_inputs(fso::generators::lhg::MAX_NODES).unwrap();
        // mask count equals node count; adjacency entries in [0,1]
        assert_eq!(mask.iter().sum::<f32>() as usize, lhg.len());
        assert!(adj.iter().all(|v| (0.0..=1.0).contains(v)));
    });
}

#[test]
fn prop_backend_oracle_outputs_are_physical() {
    check(150, 0xBACE, |rng| {
        let p = random_platform(rng);
        let arch = random_arch(rng, p);
        let e = if rng.bool(0.5) { Enablement::Gf12 } else { Enablement::Ng45 };
        let flow = SpnrFlow::new(e, rng.next_u64());
        let cfg = BackendConfig::new(rng.range(0.1, 3.0), rng.range(0.15, 0.95));
        let r = flow.run(&arch, cfg).unwrap();
        assert!(r.backend.f_effective_ghz > 0.0 && r.backend.f_effective_ghz < 5.0);
        assert!(r.backend.f_effective_ghz <= r.backend.f_max_ghz + 1e-9);
        assert!(r.backend.total_power_w() > 0.0 && r.backend.total_power_w() < 1e3);
        assert!(r.backend.chip_area_mm2 > 0.0 && r.backend.chip_area_mm2 < 1e4);
        assert!(r.backend.power.leakage_w < r.backend.total_power_w());
        assert!(r.synth.cell_area_um2 > 0.0);
    });
}

#[test]
fn prop_samplers_stay_in_bounds_and_quantize_legally() {
    check(100, 0x5A3, |rng| {
        let p = random_platform(rng);
        let space = p.param_space();
        let kind = SamplerKind::ALL[rng.below(3)];
        let mut s = Sampler::new(kind, space.len(), rng.next_u64());
        let n = 1 + rng.below(40);
        let pts = s.sample(n);
        assert_eq!(pts.len(), n);
        for vals in fso::sampling::quantize(&pts, &space) {
            let cfg = ArchConfig::new(p, vals);
            cfg.validate().unwrap();
            // every quantized value must be reachable from its own unit pos
            for (spec, v) in space.iter().zip(cfg.values.iter()) {
                let u = spec.kind.to_unit(*v);
                assert!((0.0..=1.0).contains(&u), "{p} {}: {v} -> {u}", spec.name);
            }
        }
    });
}

#[test]
fn prop_pareto_front_never_contains_dominated_members() {
    check(200, 0xFA27, |rng| {
        let mut front = ParetoFront::default();
        let n = 2 + rng.below(60);
        let dims = 2 + rng.below(3);
        for i in 0..n {
            let obj: Vec<f64> = (0..dims).map(|_| rng.range(0.0, 10.0)).collect();
            front.insert(obj, i);
        }
        for i in 0..front.len() {
            for j in 0..front.len() {
                if i != j {
                    assert!(
                        !dominates(&front.objectives[i], &front.objectives[j]),
                        "front member {j} dominated by {i}"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_dataset_splits_are_disjoint_and_complete() {
    use fso::data::Row;
    check(80, 0xD5, |rng| {
        // synthetic dataset with n archs x m backend points
        let n_arch = 2 + rng.below(6);
        let m = 2 + rng.below(8);
        let p = Platform::Axiline;
        let archs: Vec<ArchConfig> = (0..n_arch).map(|_| random_arch(rng, p)).collect();
        let lhgs = archs
            .iter()
            .map(|a| Lhg::from_tree(&p.generate(a).unwrap()))
            .collect();
        let mut rows = Vec::new();
        for ai in 0..n_arch {
            for bi in 0..m {
                let ft = 0.3 + 0.17 * bi as f64;
                rows.push(Row {
                    arch_idx: ai,
                    features: [0.1; fso::generators::FEAT_DIM],
                    f_target_ghz: ft,
                    util: 0.5,
                    power_w: 1.0,
                    f_effective_ghz: ft,
                    area_mm2: 1.0,
                    energy_j: 1.0,
                    runtime_s: 1.0,
                    in_roi: rng.bool(0.7),
                });
            }
        }
        let ds = Dataset {
            platform: p,
            enablement: Enablement::Gf12,
            archs,
            lhgs,
            rows,
        };
        let mut s1 = ds.split_unseen_backend(0.3, rng.next_u64());
        s1.validate(ds.len()).unwrap();
        assert_eq!(s1.train.len() + s1.test.len(), ds.len());
        ds.carve_validation(&mut s1, 0.25, rng.next_u64());
        s1.validate(ds.len()).unwrap();
        assert_eq!(s1.train.len() + s1.val.len() + s1.test.len(), ds.len());

        let s2 = ds.split_unseen_arch(0.3, rng.next_u64());
        s2.validate(ds.len()).unwrap();
        // no arch crosses the train/test boundary
        let train_archs: std::collections::BTreeSet<usize> =
            s2.train.iter().map(|&i| ds.rows[i].arch_idx).collect();
        for &i in &s2.test {
            assert!(!train_archs.contains(&ds.rows[i].arch_idx));
        }
    });
}

#[test]
fn prop_store_lifecycle_preserves_liveness_and_byte_determinism() {
    // ISSUE 4: arbitrary insert/get/evict/flush/compact/reopen
    // sequences against the shared store core must keep every live key
    // readable with its latest value, every evicted key a miss, and
    // shard files byte-deterministic for a given operation sequence.
    use fso::coordinator::ModelStore;
    use fso::util::json::Json;
    use std::collections::BTreeMap;
    use std::path::Path;

    #[derive(Clone, Copy)]
    enum Op {
        Put(usize, u64),   // key index, value tag
        Get(usize),
        Evict(usize),
        Flush,
        Compact,
        Reopen,
    }

    let payload = |v: u64| {
        Json::obj(vec![("w", Json::arr_f64(&[v as f64, -(v as f64)])), ("tag", Json::from(v as usize))])
    };

    check(20, 0x570E, |rng| {
        // keys spread over every shard (top byte varies), fixed space
        // so evicts and re-puts collide on purpose
        let keyspace: Vec<u64> =
            (0..10u64).map(|i| (i << 56) | (0xABC0 + i)).collect();
        let n_ops = 12 + rng.below(30);
        let ops: Vec<Op> = (0..n_ops)
            .map(|_| {
                let k = rng.below(keyspace.len());
                match rng.below(12) {
                    0..=4 => Op::Put(k, rng.next_u64() % 1000),
                    5..=6 => Op::Get(k),
                    7..=8 => Op::Evict(k),
                    9 => Op::Flush,
                    10 => Op::Compact,
                    _ => Op::Reopen,
                }
            })
            .collect();

        let run = |dir: &Path| {
            // reference model: key -> latest live value
            let mut live: BTreeMap<u64, u64> = BTreeMap::new();
            let mut store = ModelStore::open(dir).unwrap();
            for op in &ops {
                match *op {
                    Op::Put(k, v) => {
                        store.put("prop", keyspace[k], payload(v));
                        live.insert(keyspace[k], v);
                    }
                    Op::Get(k) => {
                        let got = store.get("prop", keyspace[k]);
                        match live.get(&keyspace[k]) {
                            Some(&v) => assert_eq!(
                                got,
                                Some(payload(v)),
                                "live key must read its latest value"
                            ),
                            None => assert_eq!(got, None, "non-live key must miss"),
                        }
                    }
                    Op::Evict(k) => {
                        let was_live = live.remove(&keyspace[k]).is_some();
                        assert_eq!(
                            store.evict(keyspace[k]),
                            was_live,
                            "evict must report whether a live record existed"
                        );
                    }
                    Op::Flush => {
                        store.flush().unwrap();
                    }
                    Op::Compact => {
                        store.compact().unwrap();
                    }
                    Op::Reopen => {
                        // assignment drops the old instance (flush-on-drop)
                        store = ModelStore::open(dir).unwrap();
                    }
                }
            }
            store.flush().unwrap();
            for (&key, &v) in &live {
                assert_eq!(
                    store.get("prop", key),
                    Some(payload(v)),
                    "live key lost at the end of the sequence"
                );
            }
            for &key in &keyspace {
                if !live.contains_key(&key) {
                    assert_eq!(store.get("prop", key), None, "evicted key resurfaced");
                }
            }
        };

        let tag = rng.next_u64();
        let dir_a = std::env::temp_dir()
            .join(format!("fso-prop-store-{}-{tag:016x}-a", std::process::id()));
        let dir_b = std::env::temp_dir()
            .join(format!("fso-prop-store-{}-{tag:016x}-b", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir_a);
        let _ = std::fs::remove_dir_all(&dir_b);
        run(&dir_a);
        run(&dir_b);

        // identical op sequences -> byte-identical store directories
        let listing = |dir: &Path| -> Vec<(String, Vec<u8>)> {
            let mut files: Vec<_> = std::fs::read_dir(dir)
                .unwrap()
                .map(|e| e.unwrap().path())
                .collect();
            files.sort();
            files
                .iter()
                .map(|p| {
                    (
                        p.file_name().unwrap().to_string_lossy().into_owned(),
                        std::fs::read(p).unwrap(),
                    )
                })
                .collect()
        };
        assert_eq!(
            listing(&dir_a),
            listing(&dir_b),
            "store directories must be byte-deterministic per op sequence"
        );
        let _ = std::fs::remove_dir_all(&dir_a);
        let _ = std::fs::remove_dir_all(&dir_b);
    });
}

#[test]
fn prop_single_flight_coalescing_is_invisible_and_runs_each_key_once() {
    // ISSUE 5: arbitrary interleavings of duplicate/unique keys across
    // arbitrary worker counts => the coalesced service (a) returns
    // bit-identical results to a serial uncoalesced reference, (b)
    // runs the oracle exactly once per unique key, and (c) feeds the
    // persistent store exactly once per key. The counters are
    // schedule-independent by design, so no barriers are needed —
    // whatever interleaving the scheduler produces must satisfy them.
    use fso::coordinator::{CacheStore, EvalService};
    use std::collections::BTreeSet;
    use std::sync::Arc;

    check(10, 0xC0A7, |rng| {
        let p = Platform::Axiline;
        let archs: Vec<ArchConfig> = (0..2).map(|_| random_arch(rng, p)).collect();
        let backends: Vec<BackendConfig> = (0..3)
            .map(|_| BackendConfig::new(rng.range(0.4, 1.4), rng.range(0.35, 0.75)))
            .collect();
        let n_jobs = 6 + rng.below(18);
        let jobs: Vec<(ArchConfig, BackendConfig)> = (0..n_jobs)
            .map(|_| (archs[rng.below(2)].clone(), backends[rng.below(3)]))
            .collect();
        let workers = 1 + rng.below(7);
        let seed = rng.next_u64();

        let dir = std::env::temp_dir().join(format!(
            "fso-prop-coalesce-{}-{:016x}",
            std::process::id(),
            rng.next_u64()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let store = Arc::new(CacheStore::open(&dir).unwrap());
        let coal = EvalService::new(Enablement::Gf12, seed)
            .with_workers(workers)
            .with_coalescing(true)
            .with_cache_store(Arc::clone(&store));
        let got = coal.evaluate_many(&jobs, None).unwrap();

        let reference = EvalService::new(Enablement::Gf12, seed);
        let want = reference.evaluate_many(&jobs, None).unwrap();
        for ((g, w), (arch, _)) in got.iter().zip(&want).zip(&jobs) {
            assert_eq!(g.flow.backend, w.flow.backend, "{}", arch.id_hash());
            assert_eq!(g.flow.synth, w.flow.synth);
            assert_eq!(g.system, w.system);
        }

        let unique: BTreeSet<(u64, u64, u64)> = jobs
            .iter()
            .map(|(a, b)| (a.id_hash(), b.f_target_ghz.to_bits(), b.util.to_bits()))
            .collect();
        let s = coal.stats();
        assert_eq!(s.oracle_runs, unique.len(), "w={workers}: {s}");
        assert_eq!(s.flow_runs, unique.len(), "w={workers}: {s}");
        assert_eq!(s.oracle_misses, unique.len(), "w={workers}: {s}");
        assert_eq!(s.oracle_hits, jobs.len() - unique.len(), "w={workers}: {s}");
        assert!(s.coalesced_hits <= s.oracle_hits, "{s}");
        // store fed exactly once per key: one flow + one eval record
        assert_eq!(store.stats().pending, 2 * unique.len(), "{s}");
        store.flush().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    });
}

#[test]
fn prop_simulator_metrics_scale_with_clock() {
    check(60, 0x51E, |rng| {
        let p = random_platform(rng);
        let arch = random_arch(rng, p);
        let flow = SpnrFlow::new(Enablement::Gf12, 1);
        let f1 = rng.range(0.2, 0.7);
        let f2 = f1 * rng.range(1.6, 2.4);
        let u = rng.range(0.25, 0.55);
        let r1 = flow.run(&arch, BackendConfig::new(f1, u)).unwrap();
        let r2 = flow.run(&arch, BackendConfig::new(f2, u)).unwrap();
        let m1 = fso::simulators::simulate(&arch, &r1.backend, Enablement::Gf12).unwrap();
        let m2 = fso::simulators::simulate(&arch, &r2.backend, Enablement::Gf12).unwrap();
        // strictly higher effective clock must not be slower
        if r2.backend.f_effective_ghz > r1.backend.f_effective_ghz * 1.05 {
            assert!(
                m2.runtime_s < m1.runtime_s * 1.001,
                "{p}: runtime must drop with clock ({} -> {})",
                m1.runtime_s,
                m2.runtime_s
            );
        }
    });
}
