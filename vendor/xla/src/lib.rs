//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The build environment carries no XLA/PJRT native libraries, so this
//! workspace vendors the API surface the framework uses:
//!
//! - host-side `Literal` construction/reshaping/readback **works** (it
//!   is plain Vec<f32> bookkeeping), so `Tensor` conversion round-trips
//!   and unit tests of the host side pass;
//! - device-side entry points (`PjRtClient::cpu`, `compile`, `execute`,
//!   `.npy` fixture loading) return a descriptive `Error`. Everything
//!   PJRT-dependent in the framework already gates on the presence of
//!   built artifacts and skips cleanly when they are absent.
//!
//! Swap this path dependency for the real `xla` crate (plus its native
//! library closure) to run the AOT ANN/GCN artifacts.

use std::fmt;
use std::path::Path;

/// Stub error: carries the failed operation's name.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: the XLA/PJRT runtime is not available in this offline build \
         (vendor/xla is a stub; link the real xla crate to run AOT artifacts)"
    )))
}

/// Host-side array shape (dims only — f32 everywhere in this stack).
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Conversion out of a literal's f32 storage.
pub trait NativeFromF32: Sized {
    fn native_from_f32(v: f32) -> Self;
}

impl NativeFromF32 for f32 {
    fn native_from_f32(v: f32) -> f32 {
        v
    }
}

/// Host-side literal: flat f32 storage + dims. Construction, reshape,
/// and readback are real; tuple decomposition only exists on device
/// results, so it errors here.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let want = if dims.is_empty() { 1 } else { n };
        if want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn to_vec<T: NativeFromF32>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::native_from_f32(v)).collect())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Raw-bytes readers (`.npy` fixtures) — device-independent in the real
/// crate, but unimplemented in the stub.
pub trait FromRawBytes: Sized {
    type Context;
    fn read_npy<P: AsRef<Path>>(path: P, ctx: &Self::Context) -> Result<Self>;
}

impl FromRawBytes for Literal {
    type Context = ();
    fn read_npy<P: AsRef<Path>>(path: P, _ctx: &()) -> Result<Literal> {
        unavailable(&format!("Literal::read_npy({})", path.as_ref().display()))
    }
}

/// Parsed HLO module (stub: never constructed).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        ))
    }
}

/// XLA computation wrapper.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle returned by `execute` (stub: never constructed).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Loaded executable (stub: never constructed).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client (stub: `cpu()` reports the missing runtime).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_vec1_reshape_readback() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        let back: Vec<f32> = r.to_vec().unwrap();
        assert_eq!(back, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn scalar_reshape_allowed() {
        let lit = Literal::vec1(&[7.0]);
        let s = lit.reshape(&[]).unwrap();
        assert_eq!(s.array_shape().unwrap().dims(), &[] as &[i64]);
    }

    #[test]
    fn reshape_rejects_bad_count() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0]);
        assert!(lit.reshape(&[2, 2]).is_err());
    }

    #[test]
    fn device_paths_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        let e = HloModuleProto::from_text_file("x.hlo").unwrap_err();
        assert!(format!("{e}").contains("offline"));
    }
}
