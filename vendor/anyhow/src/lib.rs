//! Offline vendored subset of the `anyhow` error-handling API.
//!
//! The build environment has no access to crates.io, so this workspace
//! carries the slice of `anyhow` the codebase actually uses as a path
//! dependency: `Error`, `Result`, the `anyhow!`/`bail!`/`ensure!`
//! macros, and the `Context` extension trait for `Result` and `Option`.
//!
//! Semantics match upstream where it matters here:
//! - `Error` is `Send + Sync` and carries a context chain;
//! - `Display` prints the outermost message, `{:#}` prints the whole
//!   chain joined by `": "` (the format `main.rs` prints);
//! - `?` converts any `std::error::Error + Send + Sync + 'static`;
//! - `.context(..)` / `.with_context(..)` wrap errors (and turn `None`
//!   into an error).

use std::fmt;

/// Error type: an ordered context chain, outermost message first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Construct from a std error, capturing its source chain.
    pub fn from_std<E: std::error::Error>(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }

    /// Push a new outermost context message.
    pub fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain_messages(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or("error"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        Error::from_std(err)
    }
}

/// `Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    use super::Error;

    /// Conversion into [`Error`], implemented for both std errors and
    /// `Error` itself (which deliberately does not implement
    /// `std::error::Error`, exactly as upstream anyhow).
    pub trait IntoAnyhow {
        fn into_anyhow(self) -> Error;
    }

    impl IntoAnyhow for Error {
        fn into_anyhow(self) -> Error {
            self
        }
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoAnyhow for E {
        fn into_anyhow(self) -> Error {
            Error::from_std(self)
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: ext::IntoAnyhow> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_anyhow().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_anyhow().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                "condition failed: {}",
                stringify!($cond)
            ));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = io_err().into();
        let e = e.wrap("loading manifest");
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: missing file");
    }

    #[test]
    fn context_on_result_and_option() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: missing file");

        let v: Option<u32> = None;
        let e = v.with_context(|| format!("n = {}", 3)).unwrap_err();
        assert_eq!(format!("{e}"), "n = 3");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(7).unwrap_err()), "unlucky 7");
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
